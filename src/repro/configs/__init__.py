"""Assigned architecture configs (--arch <id>) + reduced smoke variants.

Each module exposes ``full()`` (the exact published config) and ``smoke()``
(same family, tiny dims — one CPU train step must pass).  ``get(arch_id)``
and ``ARCHS`` are the registry the launcher uses.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "mamba2_130m",
    "granite_moe_1b_a400m",
    "dbrx_132b",
    "smollm_135m",
    "qwen15_32b",
    "deepseek_coder_33b",
    "qwen2_05b",
    "zamba2_12b",
    "internvl2_2b",
    "whisper_tiny",
]

# assigned ids (dashes) -> module names (underscores)
ALIASES = {
    "mamba2-130m": "mamba2_130m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "dbrx-132b": "dbrx_132b",
    "smollm-135m": "smollm_135m",
    "qwen1.5-32b": "qwen15_32b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-0.5b": "qwen2_05b",
    "zamba2-1.2b": "zamba2_12b",
    "internvl2-2b": "internvl2_2b",
    "whisper-tiny": "whisper_tiny",
}


VARIANTS = {
    # hillclimb variants (EXPERIMENTS.md §Perf)
    "qwen1.5-32b-pad48": ("qwen15_32b", "full_padded_heads"),
    "qwen1.5-32b-pad48-kvq": ("qwen15_32b", "full_padded_kvq"),
    "dbrx-132b-cf1": ("dbrx_132b", "full_cf1"),
}


def get(arch_id: str, smoke: bool = False):
    if arch_id in VARIANTS and not smoke:
        mod_name, fn = VARIANTS[arch_id]
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        return getattr(mod, fn)()
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke() if smoke else mod.full()
