"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="smollm-135m", family="dense", n_layers=30, d_model=576,
        n_heads=9, n_kv=3, d_ff=1536, vocab=49152, dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="smollm-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, dtype=jnp.float32)
