"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + SHARED attention block [arXiv:2411.15242].

Approximation (recorded in DESIGN.md §Arch-applicability): the 38 mamba
layers are grouped into 19 segments of 2; the single shared attention+MLP
block is applied once per segment (weight re-use, as in the paper's shared
block design)."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv=32, d_ff=8192, vocab=32000, d_state=64,
        ssm_expand=2, ssm_headdim=64, ssm_per_segment=2, dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=2, n_kv=2, d_ff=128, vocab=256, d_state=16, ssm_expand=2,
        ssm_headdim=32, ssm_per_segment=2, ssm_chunk=32, dtype=jnp.float32)
