"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512,
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="granite-moe-1b-a400m", family="moe", n_layers=24,
        d_model=1024, n_heads=16, n_kv=8, d_ff=512, vocab=49155,
        n_experts=32, top_k=8, dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="granite-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=96, vocab=256, n_experts=4, top_k=2,
        dtype=jnp.float32)
