"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch [arXiv:2401.14196]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="deepseek-coder-33b", family="dense", n_layers=62,
        d_model=7168, n_heads=56, n_kv=8, d_ff=19200, vocab=32256,
        dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="deepseek-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, dtype=jnp.float32)
