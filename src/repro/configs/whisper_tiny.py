"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536
vocab=51865 — enc-dec, conv frontend STUB (precomputed frame embeddings)
[arXiv:2212.04356]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="whisper-tiny", family="encdec", n_layers=4, dec_layers=4,
        d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
        n_frames=1500, dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="whisper-smoke", family="encdec", n_layers=2, dec_layers=2,
        d_model=64, n_heads=2, n_kv=2, d_ff=128, vocab=256, n_frames=64,
        dtype=jnp.float32)
