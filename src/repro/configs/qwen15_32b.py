"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064, QKV bias [hf:Qwen/Qwen1.5-32B family]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
        n_heads=40, n_kv=40, d_ff=27392, vocab=152064, qkv_bias=True,
        dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="qwen15-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=256, qkv_bias=True,
        dtype=jnp.float32)


def full_padded_heads(dtype=None):
    """Hillclimb variant (EXPERIMENTS.md §Perf cell A): q/kv heads padded
    40 -> 48 so heads divide the 16-way model axis.  Mathematically exact
    when the 8 extra heads' wo rows are zero; +20% attention FLOPs traded
    for shard-local decode attention (no cache all-gathers)."""
    import dataclasses
    import jax.numpy as jnp
    cfg = full(dtype or jnp.bfloat16)
    return dataclasses.replace(cfg, arch_id="qwen1.5-32b-pad48",
                               n_heads=48, n_kv=48, head_dim=128)


def full_padded_kvq(dtype=None):
    """Hillclimb cell A, iteration 2: padded heads + int8 KV cache."""
    import dataclasses
    import jax.numpy as jnp
    cfg = full_padded_heads(dtype)
    return dataclasses.replace(cfg, arch_id="qwen1.5-32b-pad48-kvq",
                               kv_quant=True)
