"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936, GQA + QKV bias [arXiv:2407.10671]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
        n_heads=14, n_kv=2, d_ff=4864, vocab=151936, qkv_bias=True,
        dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="qwen2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, qkv_bias=True,
        dtype=jnp.float32)
