"""mamba2-130m [ssm]: 24L d_model=768 attn-free, vocab=50280, ssm_state=128.
SSD (state-space duality) [arXiv:2405.21060]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="mamba2-130m", family="ssm", n_layers=24, d_model=768,
        n_heads=24, n_kv=24, d_ff=0, vocab=50280, d_state=128,
        ssm_expand=2, ssm_headdim=64, dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="mamba2-130m-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=2, n_kv=2, d_ff=0, vocab=256, d_state=16, ssm_expand=2,
        ssm_headdim=32, ssm_chunk=32, dtype=jnp.float32)
