"""Wire format: length-prefixed, codec-tagged frames with array packing.

Every message on the wire is one **frame**::

    [4-byte big-endian length N] [1 codec byte] [N-1 payload bytes]

The codec byte selects the payload encoding — ``J`` (JSON, always
available, arrays base64-wrapped) or ``M`` (msgpack, binary-native,
used when the ``msgpack`` package is importable).  The length covers the
codec byte, so a reader can bound-check before buffering and a stream can
mix codecs frame by frame (a JSON client can talk to a msgpack-preferring
master).  Frames decode to a dict with at least a ``"kind"`` key.

Robustness contract: :class:`FrameReader` is an incremental parser that
NEVER raises on partial input (it just waits for more bytes) and raises
:class:`FrameError` exactly when the stream is provably corrupt —
oversized or zero length, unknown codec byte, undecodable payload, or a
payload that is not a dict with a string ``"kind"``.  After a FrameError
the stream has no resynchronization point (the length prefix itself is
untrusted), so the owning connection must be closed; the peer's
capped-backoff reconnect recovers.  This is what the fuzz tests drive:
arbitrary byte corruption must surface as FrameError or a clean decode,
never as an unhandled exception or a hung parser.

Arrays cross the wire via :func:`pack_array` / :func:`unpack_array`
(dtype + shape + raw little-endian bytes), which round-trip bit-exactly —
the foundation of the record/replay checksum contract.
"""
from __future__ import annotations

import base64
import json
import struct
from typing import Any

import numpy as np

try:                                    # optional: the container ships it,
    import msgpack                      # CI may not — JSON is the fallback
except ImportError:                     # pragma: no cover - env dependent
    msgpack = None

MAX_FRAME = 16 * 1024 * 1024            # 16 MiB: > any sane (k, d) payload
_LEN = struct.Struct(">I")
CODEC_JSON = ord("J")
CODEC_MSGPACK = ord("M")

# frame kinds (the protocol vocabulary; field contracts live with the
# master/worker handlers that validate them)
HELLO = "hello"            # peer -> master: {"role": "worker"|"client", ...}
READY = "ready"            # worker -> master: warmed up, serving
REQ = "req"                # request: {"rid", "q", "k", "n_probe", ...}
RESP = "resp"              # response: {"rid", "dists", "ids", "checksum"}
ERR = "err"                # typed error: {"rid"?, "code", "detail"}
RETRY_AFTER = "retry_after"  # 429-style backpressure: {"rid", "delay_s"}
HB = "hb"                  # heartbeat: {"wid"}
BYE = "bye"                # orderly shutdown


class FrameError(ValueError):
    """The stream is corrupt at frame granularity; close the connection."""


def default_codec() -> str:
    return "msgpack" if msgpack is not None else "json"


def _to_jsonable(obj: Any) -> Any:
    if isinstance(obj, bytes):
        return {"__b64__": base64.b64encode(obj).decode("ascii")}
    if isinstance(obj, dict):
        return {k: _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_to_jsonable(v) for v in obj]
    return obj


def _from_jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        if set(obj) == {"__b64__"} and isinstance(obj["__b64__"], str):
            return base64.b64decode(obj["__b64__"])
        return {k: _from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_from_jsonable(v) for v in obj]
    return obj


def encode_frame(frame: dict, codec: str | None = None,
                 max_frame: int = MAX_FRAME) -> bytes:
    """One dict -> length-prefixed bytes ready for the socket."""
    codec = codec or default_codec()
    if not isinstance(frame, dict) or not isinstance(frame.get("kind"), str):
        raise FrameError(f"frame must be a dict with a str 'kind', "
                         f"got {type(frame).__name__}")
    if codec == "json":
        body = json.dumps(_to_jsonable(frame), sort_keys=True,
                          separators=(",", ":")).encode()
        tag = CODEC_JSON
    elif codec == "msgpack":
        if msgpack is None:
            raise FrameError("msgpack codec requested but the msgpack "
                             "package is not installed")
        body = msgpack.packb(frame, use_bin_type=True)
        tag = CODEC_MSGPACK
    else:
        raise FrameError(f"unknown codec {codec!r}")
    n = len(body) + 1
    if n > max_frame:
        raise FrameError(f"frame of {n} bytes exceeds max_frame={max_frame}")
    return _LEN.pack(n) + bytes([tag]) + body


def _decode_body(tag: int, body: bytes) -> dict:
    if tag == CODEC_JSON:
        try:
            obj = _from_jsonable(json.loads(body.decode("utf-8")))
        except (ValueError, UnicodeDecodeError) as e:
            raise FrameError(f"undecodable JSON frame: {e}") from e
    elif tag == CODEC_MSGPACK:
        if msgpack is None:
            raise FrameError("received a msgpack frame but the msgpack "
                             "package is not installed")
        try:
            obj = msgpack.unpackb(body, raw=False, strict_map_key=False)
        except Exception as e:            # msgpack raises a zoo of types
            raise FrameError(f"undecodable msgpack frame: {e}") from e
    else:
        raise FrameError(f"unknown codec byte {tag:#04x}")
    if not isinstance(obj, dict) or not isinstance(obj.get("kind"), str):
        raise FrameError("frame payload is not a dict with a str 'kind'")
    return obj


class FrameReader:
    """Incremental frame parser over an untrusted byte stream."""

    def __init__(self, max_frame: int = MAX_FRAME):
        self.max_frame = int(max_frame)
        self._buf = bytearray()

    def pending(self) -> int:
        """Bytes buffered but not yet parsed (mid-frame)."""
        return len(self._buf)

    def feed(self, data: bytes) -> list[dict]:
        """Append ``data``; return every complete frame it finished.

        Raises :class:`FrameError` on provable corruption; the reader is
        then poisoned (the buffer is cleared) and the caller must close
        the connection.
        """
        self._buf.extend(data)
        out: list[dict] = []
        while True:
            if len(self._buf) < _LEN.size:
                return out
            (n,) = _LEN.unpack_from(self._buf)
            if n < 1 or n > self.max_frame:
                self._buf.clear()
                raise FrameError(
                    f"frame length {n} outside (0, {self.max_frame}]")
            if len(self._buf) < _LEN.size + n:
                return out
            tag = self._buf[_LEN.size]
            body = bytes(self._buf[_LEN.size + 1:_LEN.size + n])
            del self._buf[:_LEN.size + n]
            try:
                out.append(_decode_body(tag, body))
            except FrameError:
                self._buf.clear()
                raise


# --------------------------------------------------------------------------
# Array packing (bit-exact round trip)
# --------------------------------------------------------------------------

_ALLOWED_DTYPES = ("float32", "float64", "int32", "int64", "uint32",
                   "uint64", "float16", "int16", "uint16", "int8", "uint8")


def pack_array(arr: np.ndarray) -> dict:
    """ndarray -> {"dtype", "shape", "data"} with raw C-order bytes."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype.name not in _ALLOWED_DTYPES:
        raise FrameError(f"unsupported array dtype {arr.dtype.name!r}")
    return {"dtype": arr.dtype.name, "shape": list(arr.shape),
            "data": arr.tobytes()}


def unpack_array(obj: Any, max_elems: int = 1 << 24) -> np.ndarray:
    """Inverse of :func:`pack_array`, validating every field (this runs on
    untrusted input at the request boundary)."""
    if not isinstance(obj, dict):
        raise FrameError(f"packed array must be a dict, "
                         f"got {type(obj).__name__}")
    dtype, shape, data = obj.get("dtype"), obj.get("shape"), obj.get("data")
    if dtype not in _ALLOWED_DTYPES:
        raise FrameError(f"unsupported array dtype {dtype!r}")
    if not isinstance(shape, list) or not shape or \
            not all(isinstance(s, int) and 0 < s for s in shape):
        raise FrameError(f"bad array shape {shape!r}")
    n = int(np.prod(shape, dtype=np.int64))
    if n > max_elems:
        raise FrameError(f"array of {n} elements exceeds cap {max_elems}")
    if not isinstance(data, (bytes, bytearray)):
        raise FrameError("array data must be bytes")
    dt = np.dtype(dtype)
    if len(data) != n * dt.itemsize:
        raise FrameError(
            f"array data is {len(data)} bytes, expected {n * dt.itemsize}")
    return np.frombuffer(bytes(data), dtype=dt).reshape(shape)
