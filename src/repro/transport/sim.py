"""Loopback simulation driver: MasterCore + simulated workers + wire shim
on a virtual clock.

This is the third driver over the same :class:`~repro.transport.core.
MasterCore` (live sockets and replay are the others): workers are modeled
as single-executor FIFO servers with a caller-supplied deterministic
``exec_fn`` and ``service_fn``, the wire applies a seeded
:class:`~repro.serving.faults.WireSchedule` at frame granularity in both
directions, heartbeats flow as real frames (and are therefore subject to
wire faults, exactly like the socket path), and worker kills / respawns
follow a declarative schedule.  Everything runs on one ``heapq`` timeline
with explicit tie-breaks, so a seeded (trace, schedule) pair replays
byte-identically — which is what lets the property tests draw random
trace x wire-fault-schedule pairs and assert conservation, and what lets
the record/replay tests exercise the full transcript contract without
spawning a single process.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, Sequence

import numpy as np

from repro.serving import faults as flt
from repro.serving.batcher import ShapeBucket, bucket_of
from repro.serving.queue import Request
from repro.transport.core import MasterCore
from repro.transport.wire import Transcript, WireShim

ExecFn = Callable[[np.ndarray, int, int], tuple[np.ndarray, np.ndarray]]
ServiceFn = Callable[[ShapeBucket], float]


class _SimWorker:
    """Single-executor worker model: FIFO queue, busy-until clock."""

    def __init__(self, wid: int):
        self.wid = wid
        self.alive = True
        self.connected = False
        self.busy_until = 0.0
        self.queue: deque = deque()
        self.gen = 0                    # bumps on kill; stale work discarded


class LoopbackSim:
    """Virtual-clock transport run over one ``MasterCore``."""

    def __init__(self, core: MasterCore, exec_fn: ExecFn,
                 service_fn: ServiceFn, *,
                 wire: flt.WireSchedule | None = None,
                 kill_at: dict[int, float] | None = None,
                 reconnect_delay: float = 0.02,
                 respawn_delay: float = 0.1,
                 record: bool = False):
        self.core = core
        self.exec_fn = exec_fn
        self.service_fn = service_fn
        self.shim = WireShim(wire)
        self.kill_at = dict(kill_at or {})
        self.reconnect_delay = float(reconnect_delay)
        self.respawn_delay = float(respawn_delay)
        self.workers = [_SimWorker(w) for w in range(core.cfg.n_workers)]
        self.replies: list[tuple[int, dict]] = []    # (conn, frame)
        self.transcript = Transcript() if record else None
        self._heap: list = []
        self._seq = itertools.count()

    # -- timeline helpers ----------------------------------------------------

    def _push(self, t: float, kind: str, data=None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    def _record(self, entry: dict) -> None:
        if self.transcript is not None:
            self.transcript.append(entry)

    def _core(self, ev: dict) -> None:
        """Feed one event to the core, record it, execute the actions."""
        if ev["ev"] == "resp":
            entry = dict(ev)
            entry["n_ids"] = int(len(ev["ids"]))
            entry["ck_ok"] = bool(
                flt.payload_checksum(ev["dists"], ev["ids"])
                == int(ev["checksum"]))
            self._record(entry)
        else:
            self._record(dict(ev))
        for act in self.core.handle(ev):
            if act[0] == "timer":
                _, t_at, tev = act
                self._push(t_at, "core", tev)
            elif act[0] == "reply":
                self.replies.append((act[1], act[2]))
            elif act[0] == "send":
                self._send_up(act[1], act[2], ev["t"])

    # -- wire: master -> worker ----------------------------------------------

    def _send_up(self, wid: int, frame: dict, t: float) -> None:
        w = self.workers[wid]
        if not w.connected or not w.alive:
            return                      # dispatch raced a dead link
        d = self.shim.decide(wid, "up")
        if d.kind is not None:
            self._record({"ev": "fault", "t": t, "wid": wid, "dir": "up",
                          "kind": d.kind, "delay": d.delay})
        if d.kind == flt.WIRE_DROP:
            return
        if d.kind in (flt.WIRE_TRUNCATE, flt.WIRE_DISCONNECT):
            self._disconnect(wid, t)
            return
        n = 2 if d.kind == flt.WIRE_DUP else 1
        for _ in range(n):
            self._push(t + d.delay, "deliver_up", (wid, w.gen, dict(frame)))

    def _on_deliver_up(self, wid: int, gen: int, frame: dict,
                       t: float) -> None:
        w = self.workers[wid]
        if not w.alive or not w.connected or gen != w.gen:
            return
        if frame["kind"] != "req":
            return
        bucket = bucket_of(int(frame["k"]), int(frame["n_probe"]),
                           self.core.cfg.ceilings, 1)
        start = max(t, w.busy_until)
        done = start + self.service_fn(bucket)
        w.busy_until = done
        self._push(done, "exec_done", (wid, w.gen, dict(frame)))

    def _on_exec_done(self, wid: int, gen: int, frame: dict,
                      t: float) -> None:
        w = self.workers[wid]
        if not w.alive or not w.connected or gen != w.gen:
            return
        dists, ids = self.exec_fn(np.asarray(frame["q"]), int(frame["k"]),
                                  int(frame["n_probe"]))
        resp = {"kind": "resp", "rid": frame["rid"], "wid": wid,
                "dists": dists, "ids": ids,
                "checksum": flt.payload_checksum(dists, ids),
                "k": int(frame["k"]), "n_probe": int(frame["n_probe"])}
        self._send_down(wid, resp, t)

    # -- wire: worker -> master ----------------------------------------------

    def _send_down(self, wid: int, frame: dict, t: float) -> None:
        w = self.workers[wid]
        if not w.connected or not w.alive:
            return
        d = self.shim.decide(wid, "down")
        if d.kind is not None:
            self._record({"ev": "fault", "t": t, "wid": wid, "dir": "down",
                          "kind": d.kind, "delay": d.delay})
        if d.kind == flt.WIRE_DROP:
            return
        if d.kind in (flt.WIRE_TRUNCATE, flt.WIRE_DISCONNECT):
            self._disconnect(wid, t)
            return
        n = 2 if d.kind == flt.WIRE_DUP else 1
        for _ in range(n):
            self._push(t + d.delay, "deliver_down", (wid, dict(frame)))

    def _on_deliver_down(self, wid: int, frame: dict, t: float) -> None:
        if frame["kind"] == "resp":
            self._core({"ev": "resp", "t": t, "wid": wid,
                        "rid": frame["rid"], "dists": frame["dists"],
                        "ids": frame["ids"],
                        "checksum": frame["checksum"]})
        elif frame["kind"] == "hb":
            self._core({"ev": "hb", "t": t, "wid": wid})
        elif frame["kind"] == "err":
            self._core({"ev": "werr", "t": t, "wid": wid,
                        "rid": frame["rid"], "code": frame["code"]})

    # -- link / process lifecycle --------------------------------------------

    def _disconnect(self, wid: int, t: float) -> None:
        w = self.workers[wid]
        if not w.connected:
            return
        w.connected = False
        w.queue.clear()
        w.gen += 1                      # in-progress work dies with the conn
        self._core({"ev": "lost", "t": t, "wid": wid})
        if w.alive:
            self._push(t + self.reconnect_delay, "reconnect",
                       (wid, False))

    def _on_kill(self, wid: int, t: float) -> None:
        w = self.workers[wid]
        if not w.alive:
            return
        w.alive = False
        w.gen += 1
        if w.connected:
            w.connected = False
            self._core({"ev": "lost", "t": t, "wid": wid})
        self._push(t + self.respawn_delay, "reconnect", (wid, True))

    def _on_reconnect(self, wid: int, respawned: bool, t: float) -> None:
        w = self.workers[wid]
        if respawned:
            w.alive = True
        if not w.alive or w.connected:
            return
        w.connected = True
        w.busy_until = t
        self._core({"ev": "up", "t": t, "wid": wid,
                    "respawned": respawned})
        self._push(t + self.core.cfg.hb_interval, "worker_hb", wid)

    def _on_worker_hb(self, wid: int, t: float) -> None:
        w = self.workers[wid]
        if not w.alive or not w.connected:
            return
        self._send_down(wid, {"kind": "hb", "wid": wid}, t)
        self._push(t + self.core.cfg.hb_interval, "worker_hb", wid)

    # -- the run -------------------------------------------------------------

    def _svc_seed(self, trace: Sequence[Request]) -> dict[str, float]:
        ceilings = self.core.cfg.ceilings
        buckets = {bucket_of(min(r.k, ceilings[-1]), r.n_probe, ceilings, 1)
                   for r in trace}
        return {f"{b.k},{b.n_probe}": float(self.service_fn(b))
                for b in sorted(buckets)}

    def run(self, trace: Sequence[Request],
            settle: float = 5.0) -> list:
        """Drive the whole trace; returns outcomes in rid order.

        Client requests enter at their ``arrival`` times with
        ``deadline - arrival`` as the relative deadline; ``conn`` is 0 and
        ``crid`` is the trace rid.  ``settle`` bounds how long past the
        last event the sim keeps processing timers (heartbeats re-arm
        forever, so the loop stops once every request is terminal)."""
        trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        t0 = trace[0].arrival if trace else 0.0
        if self.transcript is not None:
            self.transcript.header = {
                "t0": t0, "n_workers": self.core.cfg.n_workers,
                "ceilings": list(self.core.cfg.ceilings),
                "wire": (self.shim.schedule.to_dict()
                         if self.shim.schedule else None)}
        self.core.start(t0)
        svc = self._svc_seed(trace)
        for w in self.workers:
            w.busy_until = t0
            w.connected = True
            self._core({"ev": "up", "t": t0, "wid": w.wid,
                        "respawned": False, "svc": svc})
            self._push(t0 + self.core.cfg.hb_interval, "worker_hb", w.wid)
        for wid, t_kill in sorted(self.kill_at.items()):
            self._push(t_kill, "kill", wid)
        for req in trace:
            self._push(req.arrival, "client_req", req)
        n_expected = len(trace)
        t_last = t0
        while self._heap:
            t, _, kind, data = heapq.heappop(self._heap)
            if len(self.core.outcomes) >= n_expected and \
                    self.core.idle():
                break
            if t > t_last + settle and len(self.core.outcomes) \
                    >= n_expected:
                break
            t_last = max(t_last, t)
            if kind == "client_req":
                req = data
                self._core({"ev": "req", "t": t, "conn": 0,
                            "crid": req.rid, "rid": req.rid, "q": req.q,
                            "k": req.k, "n_probe": req.n_probe,
                            "deadline_s": req.deadline - req.arrival})
            elif kind == "core":
                ev = dict(data)
                ev["t"] = t
                self._core(ev)
            elif kind == "deliver_up":
                self._on_deliver_up(*data, t)
            elif kind == "exec_done":
                self._on_exec_done(*data, t)
            elif kind == "deliver_down":
                self._on_deliver_down(*data, t)
            elif kind == "kill":
                self._on_kill(data, t)
            elif kind == "reconnect":
                self._on_reconnect(*data, t)
            elif kind == "worker_hb":
                self._on_worker_hb(data, t)
        if self.transcript is not None:
            self._record({"ev": "end", "t": t_last})
        return self.core.outcome_list()
