"""Replay driver: transcript -> fresh MasterCore -> byte-identical digest.

Replay feeds a recorded run's ordered core events into a brand-new
:class:`~repro.transport.core.MasterCore`.  Because the core is pure over
its event sequence, every routing choice, retry, rejection, cache hit and
outcome is reproduced exactly — ``outcome_digest`` over the replayed
outcomes must equal the live run's digest byte for byte.

Response payloads are NOT in the transcript (see
:mod:`repro.transport.wire`): each ``resp`` event is re-executed through
an in-process ``exec_fn`` built from the same engine spec the workers
used, and the recomputed payload checksum is verified against the
recorded one.  A mismatch means the engine is not deterministic across
processes — exactly the failure this contract exists to catch — and
raises :class:`ReplayError` under ``strict`` (the default).

Two recorded facts stand in for the missing payload when re-execution
must NOT produce a clean response:

* ``ck_ok`` — whether the live payload matched its checksum;
* ``n_ids`` — the live payload's row count.

When either says the live core took the corrupt-response path, replay
feeds a synthetic payload engineered to fail verification the same way,
so the replayed core's control flow tracks the live one exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serving import faults as flt
from repro.serving.router import outcome_digest
from repro.transport.core import MasterConfig, MasterCore
from repro.transport.wire import Transcript


class ReplayError(RuntimeError):
    """Replayed execution diverged from the recorded run."""


@dataclass
class ReplayResult:
    core: MasterCore
    outcomes: list
    replies: list[tuple[int, dict]]
    digest: str
    checksum_mismatches: list[tuple[int, int, int]] = field(
        default_factory=list)          # (rid, recorded, recomputed)


def _corrupt_stand_in(n_ids: int, want_k: int) -> tuple:
    """A payload guaranteed to fail the core's response verification."""
    n = max(int(n_ids), 1)
    dists = np.zeros(n, dtype=np.float32)
    ids = np.zeros(n, dtype=np.int64)
    ck = flt.payload_checksum(dists, ids)
    if n == int(want_k):               # length passes -> break the checksum
        ck = (ck + 1) & 0xFFFFFFFF
    return dists, ids, ck


def replay_transcript(transcript: Transcript, cfg: MasterConfig,
                      centroids: np.ndarray, exec_fn, *,
                      strict: bool = True) -> ReplayResult:
    """Run the recorded event sequence through a fresh core.

    ``exec_fn(q, k, n_probe) -> (dists, ids)`` must be built from the same
    engine spec as the live workers (see
    :func:`repro.transport.enginehost.make_exec_fn`).
    """
    core = MasterCore(cfg, centroids)
    core.start(float(transcript.header.get("t0", 0.0)))
    replies: list[tuple[int, dict]] = []
    mismatches: list[tuple[int, int, int]] = []
    for recorded in transcript.core_events():
        ev = dict(recorded)
        if ev["ev"] == "resp":
            rid = ev["rid"]
            track = core._tracks.get(rid)
            if track is None or track.done:
                # late/duplicate delivery: the core ignores the payload
                # before touching it, so any stand-in works
                ev["dists"] = np.zeros(1, dtype=np.float32)
                ev["ids"] = np.zeros(1, dtype=np.int64)
            else:
                want_k = track.req.k
                accepted = bool(ev.get("ck_ok")) and \
                    int(ev.get("n_ids", -1)) == want_k
                if accepted:
                    dists, ids = exec_fn(track.req.q, want_k,
                                         track.req.n_probe)
                    ck = flt.payload_checksum(dists, ids)
                    if ck != int(ev["checksum"]):
                        mismatches.append((rid, int(ev["checksum"]), ck))
                        if strict:
                            raise ReplayError(
                                f"rid {rid}: replayed payload checksum "
                                f"{ck} != recorded {ev['checksum']} — "
                                f"engine is not deterministic across "
                                f"processes")
                    # feed the recomputed checksum so the replayed core
                    # accepts, matching the live control flow even when a
                    # non-strict mismatch is being tolerated
                    ev["dists"], ev["ids"], ev["checksum"] = dists, ids, ck
                else:
                    dists, ids, ck = _corrupt_stand_in(
                        ev.get("n_ids", 1), want_k)
                    ev["dists"], ev["ids"], ev["checksum"] = dists, ids, ck
        for act in core.handle(ev):
            if act[0] == "reply":
                replies.append((act[1], act[2]))
            # "send"/"timer" actions are not re-driven: their consequences
            # (the response that came back, the timer that fired) are
            # already events later in the transcript
    outcomes = core.outcome_list()
    return ReplayResult(core=core, outcomes=outcomes, replies=replies,
                        digest=outcome_digest(outcomes),
                        checksum_mismatches=mismatches)
