"""Live socket driver: listener, worker supervision, wire shim, recording.

``MasterServer`` is the wall-clock shell around the pure
:class:`~repro.transport.core.MasterCore`:

* ONE non-blocking listener (Unix or TCP); workers and clients both dial
  it and declare their role in a ``hello`` frame;
* worker subprocesses are spawned from the engine spec, supervised by
  polling their exit codes, and respawned on death (the reconnect itself
  is the worker's job — the supervisor only restarts dead processes);
* every frame to or from a worker crosses the :class:`WireShim`: drops,
  duplicates, seeded latency (delayed via the timer heap), truncated
  writes and forced disconnects — the transport-level extension of the
  ``serving.faults`` taxonomy, applied at the real socket boundary;
* every core event is recorded (with ``resp`` payload facts reduced to
  checksum/row-count, see :mod:`repro.transport.wire`) so a live run can
  be replayed to a byte-identical ``outcome_digest``;
* graceful drain: on request (serve.py wires SIGTERM/SIGINT to it) the
  core rejects new work with ``retry_after`` frames, in-flight requests
  finish, then workers get ``bye`` and the process exits cleanly.

The loop is intentionally single-threaded: selectors + a timer heap give
deterministic-enough scheduling, and all policy lives in the core where
determinism is exact.
"""
from __future__ import annotations

import heapq
import itertools
import json
import os
import selectors
import socket
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.serving import faults as flt
from repro.serving.clock import Clock, SystemClock
from repro.transport import frames
from repro.transport.core import MasterConfig, MasterCore
from repro.transport.enginehost import build_state_from_spec
from repro.transport.wire import Transcript, WireShim


def unix_addr(path: str) -> dict:
    return {"family": "unix", "path": path}


def tcp_addr(host: str, port: int) -> dict:
    return {"family": "tcp", "host": host, "port": int(port)}


class _Conn:
    """Per-connection state: role, parser, write buffer."""

    def __init__(self, cid: int, sock: socket.socket):
        self.cid = cid
        self.sock = sock
        self.role: str | None = None    # None until hello; "worker"/"client"
        self.wid: int | None = None
        self.reader = frames.FrameReader()
        self.out = bytearray()
        self.closing = False            # flush remaining bytes, then close
        self.last_rx = 0.0


class MasterServer:
    """Wall-clock front-end over one :class:`MasterCore`."""

    def __init__(self, cfg: MasterConfig, spec: dict, *,
                 addr: dict | None = None, codec: str | None = None,
                 wire: flt.WireSchedule | None = None, record: bool = False,
                 clock: Clock | None = None, run_dir: str | None = None,
                 spawn_workers: bool = True, respawn: bool = True,
                 conn_idle_timeout: float = 30.0,
                 drain_timeout: float = 10.0):
        self.cfg = cfg
        self.spec = dict(spec)
        self.codec = codec or frames.default_codec()
        self.clock = clock or SystemClock()
        self.run_dir = run_dir or tempfile.mkdtemp(prefix="repro-net-")
        os.makedirs(self.run_dir, exist_ok=True)
        self.addr = addr or unix_addr(os.path.join(self.run_dir, "master.sock"))
        self.shim = WireShim(wire)
        self.spawn_workers = spawn_workers
        self.respawn = respawn
        self.conn_idle_timeout = float(conn_idle_timeout)
        self.drain_timeout = float(drain_timeout)
        state, _ = build_state_from_spec(spec)
        self.core = MasterCore(cfg, state.centroids)
        self.transcript = Transcript() if record else None
        self.sel = selectors.DefaultSelector()
        self.listener: socket.socket | None = None
        self.conns: dict[int, _Conn] = {}
        self._cid = itertools.count(1)
        self.worker_conn: dict[int, _Conn] = {}     # wid -> live conn
        self.procs: dict[int, subprocess.Popen] = {}
        self._respawned: set[int] = set()
        self._timers: list = []                     # (t, seq, payload)
        self._tseq = itertools.count()
        self._drain_started: float | None = None
        self.stopped = False

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self.addr["family"] == "unix":
            path = self.addr["path"]
            if os.path.exists(path):
                os.unlink(path)
            self.listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self.listener.bind(path)
        else:
            self.listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self.listener.bind((self.addr["host"], self.addr["port"]))
            self.addr = tcp_addr(*self.listener.getsockname())
        self.listener.listen(64)
        self.listener.setblocking(False)
        self.sel.register(self.listener, selectors.EVENT_READ, "accept")
        self.core.start(self.clock.now())
        if self.transcript is not None:
            self.transcript.header = {
                "t0": self.clock.now(), "n_workers": self.cfg.n_workers,
                "ceilings": list(self.cfg.ceilings),
                "wire": self.shim.schedule.to_dict()
                if self.shim.schedule else None}
        if self.spawn_workers:
            for wid in range(self.cfg.n_workers):
                self._spawn(wid)

    def _worker_spec(self, wid: int) -> dict:
        return {"wid": wid, "addr": self.addr, "codec": self.codec,
                "engine": self.spec,
                "hb_interval": self.cfg.hb_interval}

    def _spawn(self, wid: int) -> None:
        path = os.path.join(self.run_dir, f"worker{wid}.json")
        with open(path, "w") as f:
            json.dump(self._worker_spec(wid), f)
        log = open(os.path.join(self.run_dir, f"worker{wid}.log"), "ab")
        self.procs[wid] = subprocess.Popen(
            [sys.executable, "-m", "repro.transport.worker", path],
            stdout=log, stderr=log, env=dict(os.environ))
        log.close()

    # -- recording + core feed -----------------------------------------------

    def _feed(self, ev: dict) -> None:
        """Record one core event, hand it to the core, run the actions."""
        if self.transcript is not None:
            if ev["ev"] == "resp":
                entry = {k: v for k, v in ev.items()
                         if k not in ("dists", "ids")}
                entry["n_ids"] = int(len(ev["ids"]))
                entry["ck_ok"] = bool(
                    flt.payload_checksum(ev["dists"], ev["ids"])
                    == int(ev["checksum"]))
                self.transcript.append(entry)
            else:
                self.transcript.append(dict(ev))
        for act in self.core.handle(ev):
            if act[0] == "timer":
                self._push_timer(act[1], ("core", act[2]))
            elif act[0] == "reply":
                self._reply(act[1], act[2])
            elif act[0] == "send":
                self._send_worker(act[1], act[2])

    def _push_timer(self, t_at: float, payload: tuple) -> None:
        heapq.heappush(self._timers, (t_at, next(self._tseq), payload))

    # -- outbound ------------------------------------------------------------

    def _enqueue_bytes(self, conn: _Conn, data: bytes) -> None:
        conn.out.extend(data)
        try:
            self.sel.modify(conn.sock, selectors.EVENT_READ
                            | selectors.EVENT_WRITE, conn)
        except (KeyError, ValueError):
            pass

    def _reply(self, cid: int, frame: dict) -> None:
        conn = self.conns.get(cid)
        if conn is None or conn.closing:
            return
        wire_frame = dict(frame)
        for key in ("dists", "ids"):
            if isinstance(wire_frame.get(key), np.ndarray):
                wire_frame[key] = frames.pack_array(wire_frame[key])
        self._enqueue_bytes(conn, frames.encode_frame(wire_frame, self.codec))

    def _send_worker(self, wid: int, frame: dict) -> None:
        conn = self.worker_conn.get(wid)
        if conn is None or conn.closing:
            return
        wire_frame = dict(frame)
        if isinstance(wire_frame.get("q"), np.ndarray):
            wire_frame["q"] = frames.pack_array(wire_frame["q"])
        data = frames.encode_frame(wire_frame, self.codec)
        d = self.shim.decide(wid, "up")
        now = self.clock.now()
        if d.kind is not None and self.transcript is not None:
            self.transcript.append({"ev": "fault", "t": now, "wid": wid,
                                    "dir": "up", "kind": d.kind,
                                    "delay": d.delay})
        if d.kind == flt.WIRE_DROP:
            return
        if d.kind == flt.WIRE_TRUNCATE:
            try:                       # partial prefix, then a hard close
                conn.sock.send(data[:max(1, len(data) // 2)])
            except OSError:
                pass
            self._close_conn(conn, now)
            return
        if d.kind == flt.WIRE_DISCONNECT:
            self._close_conn(conn, now)
            return
        n = 2 if d.kind == flt.WIRE_DUP else 1
        for _ in range(n):
            if d.delay > 0:
                self._push_timer(now + d.delay, ("tx", wid, data))
            else:
                self._enqueue_bytes(conn, data)

    # -- inbound -------------------------------------------------------------

    def _accept(self) -> None:
        while True:
            try:
                sock, _ = self.listener.accept()
            except BlockingIOError:
                return
            sock.setblocking(False)
            if self.addr["family"] == "tcp":
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Conn(next(self._cid), sock)
            conn.last_rx = self.clock.now()
            self.conns[conn.cid] = conn
            self.sel.register(sock, selectors.EVENT_READ, conn)

    def _close_conn(self, conn: _Conn, now: float) -> None:
        if conn.cid not in self.conns:
            return
        del self.conns[conn.cid]
        try:
            self.sel.unregister(conn.sock)
        except (KeyError, ValueError):
            pass
        conn.sock.close()
        conn.closing = True
        if conn.role == "worker" and \
                self.worker_conn.get(conn.wid) is conn:
            del self.worker_conn[conn.wid]
            self._feed({"ev": "lost", "t": now, "wid": conn.wid})

    def _on_readable(self, conn: _Conn) -> None:
        now = self.clock.now()
        try:
            data = conn.sock.recv(262144)
        except BlockingIOError:
            return
        except OSError:
            self._close_conn(conn, now)
            return
        if not data:
            self._close_conn(conn, now)
            return
        conn.last_rx = now
        try:
            parsed = conn.reader.feed(data)
        except frames.FrameError as e:
            # stream-level corruption: no resync point -> typed error, close
            if conn.role != "worker":
                try:
                    conn.sock.send(frames.encode_frame(
                        {"kind": frames.ERR, "rid": -1, "code": "bad_frame",
                         "detail": str(e)}, self.codec))
                except OSError:
                    pass
            self._close_conn(conn, now)
            return
        for frame in parsed:
            if conn.closing:            # a shim disconnect mid-batch
                return
            self._on_frame(conn, frame, now)

    def _on_frame(self, conn: _Conn, frame: dict, now: float) -> None:
        kind = frame.get("kind")
        if kind == frames.HELLO:
            role = frame.get("role")
            if role == "worker" and isinstance(frame.get("wid"), int) and \
                    0 <= frame["wid"] < self.cfg.n_workers:
                conn.role, conn.wid = "worker", frame["wid"]
                stale = self.worker_conn.get(conn.wid)
                if stale is not None and stale is not conn:
                    self._close_conn(stale, now)
                self.worker_conn[conn.wid] = conn
            else:
                conn.role = "client"
            return
        if conn.role == "worker":
            self._on_worker_frame(conn, frame, now)
        else:
            self._on_client_frame(conn, frame, now)

    def _on_worker_frame(self, conn: _Conn, frame: dict,
                         now: float) -> None:
        wid = conn.wid
        kind = frame.get("kind")
        if kind == frames.READY:
            self._feed({"ev": "up", "t": now, "wid": wid,
                        "respawned": wid in self._respawned,
                        "svc": frame.get("svc") or {}})
            self._respawned.discard(wid)
            return
        d = self.shim.decide(wid, "down")
        if d.kind is not None and self.transcript is not None:
            self.transcript.append({"ev": "fault", "t": now, "wid": wid,
                                    "dir": "down", "kind": d.kind,
                                    "delay": d.delay})
        if d.kind == flt.WIRE_DROP:
            return
        if d.kind in (flt.WIRE_TRUNCATE, flt.WIRE_DISCONNECT):
            self._close_conn(conn, now)
            return
        ev = self._worker_event(wid, frame, now)
        if ev is None:
            return
        reps = 2 if d.kind == flt.WIRE_DUP else 1
        for i in range(reps):
            if d.delay > 0:
                self._push_timer(now + d.delay, ("core", ev))
            else:
                e = dict(ev)
                e["t"] = self.clock.now()
                self._feed(e)

    def _worker_event(self, wid: int, frame: dict,
                      now: float) -> dict | None:
        kind = frame.get("kind")
        if kind == frames.HB:
            return {"ev": "hb", "t": now, "wid": wid}
        if kind == frames.RESP:
            try:
                dists = frames.unpack_array(frame["dists"])
                ids = frames.unpack_array(frame["ids"])
                rid = int(frame["rid"])
                checksum = int(frame["checksum"])
            except (frames.FrameError, KeyError, TypeError, ValueError):
                return None             # unusable response; timeout recovers
            return {"ev": "resp", "t": now, "wid": wid, "rid": rid,
                    "dists": dists, "ids": ids, "checksum": checksum}
        if kind == frames.ERR:
            rid = frame.get("rid")
            if not isinstance(rid, int):
                return None
            return {"ev": "werr", "t": now, "wid": wid, "rid": rid,
                    "code": str(frame.get("code", "unknown"))}
        return None

    def _on_client_frame(self, conn: _Conn, frame: dict,
                         now: float) -> None:
        kind = frame.get("kind")
        if kind == frames.BYE:
            self._close_conn(conn, now)
            return
        if kind != frames.REQ:
            self._reply(conn.cid, {"kind": frames.ERR, "rid": -1,
                                   "code": "bad_kind",
                                   "detail": f"unexpected {kind!r}"})
            return
        crid = frame.get("rid")
        if not isinstance(crid, int):
            self._reply(conn.cid, {"kind": frames.ERR, "rid": -1,
                                   "code": "bad_request",
                                   "detail": "missing int rid"})
            return
        try:
            q = frames.unpack_array(frame.get("q"))
        except frames.FrameError as e:
            self._reply(conn.cid, {"kind": frames.ERR, "rid": crid,
                                   "code": "bad_request", "detail": str(e)})
            return
        self._feed({"ev": "req", "t": now, "conn": conn.cid, "crid": crid,
                    "q": q, "k": frame.get("k"),
                    "n_probe": frame.get("n_probe"),
                    "deadline_s": frame.get("deadline_s", 1.0)})

    # -- supervision ---------------------------------------------------------

    def _poll_workers(self, now: float) -> None:
        if not self.spawn_workers:
            return
        for wid, proc in list(self.procs.items()):
            if proc.poll() is None:
                continue
            conn = self.worker_conn.get(wid)
            if conn is not None:
                self._close_conn(conn, now)
            if self.respawn and self._drain_started is None:
                self._respawned.add(wid)
                self._spawn(wid)

    def _sweep_idle(self, now: float) -> None:
        for conn in list(self.conns.values()):
            if conn.role == "worker":
                continue                # workers are health-checked by HB
            if now - conn.last_rx > self.conn_idle_timeout:
                self._close_conn(conn, now)

    # -- timers --------------------------------------------------------------

    def _fire_timers(self, now: float) -> None:
        while self._timers and self._timers[0][0] <= now:
            _, _, payload = heapq.heappop(self._timers)
            if payload[0] == "core":
                ev = dict(payload[1])
                ev["t"] = self.clock.now()
                self._feed(ev)
            elif payload[0] == "tx":
                _, wid, data = payload
                conn = self.worker_conn.get(wid)
                if conn is not None and not conn.closing:
                    self._enqueue_bytes(conn, data)

    # -- the loop ------------------------------------------------------------

    def step(self, max_wait: float = 0.05) -> None:
        """One select round: I/O, due timers, supervisor poll."""
        now = self.clock.now()
        timeout = max_wait
        if self._timers:
            timeout = min(timeout, max(self._timers[0][0] - now, 0.0))
        for key, mask in self.sel.select(timeout):
            if key.data == "accept":
                self._accept()
                continue
            conn = key.data
            if mask & selectors.EVENT_WRITE:
                self._flush(conn)
            if mask & selectors.EVENT_READ:
                self._on_readable(conn)
        now = self.clock.now()
        self._fire_timers(now)
        self._poll_workers(now)
        self._sweep_idle(now)

    def _flush(self, conn: _Conn) -> None:
        if conn.cid not in self.conns:
            return
        try:
            if conn.out:
                n = conn.sock.send(bytes(conn.out))
                del conn.out[:n]
        except BlockingIOError:
            return
        except OSError:
            self._close_conn(conn, self.clock.now())
            return
        if not conn.out:
            try:
                self.sel.modify(conn.sock, selectors.EVENT_READ, conn)
            except (KeyError, ValueError):
                pass

    def serve(self, until=None, timeout: float | None = None) -> None:
        """Run until ``until()`` is true, the drain completes, or
        ``timeout`` seconds pass."""
        t_end = None if timeout is None else self.clock.now() + timeout
        while not self.stopped:
            if until is not None and until():
                return
            if self._drain_started is not None:
                if self.core.idle() or self.clock.now() - \
                        self._drain_started > self.drain_timeout:
                    self.shutdown()
                    return
            if t_end is not None and self.clock.now() > t_end:
                return
            self.step()

    # -- drain / shutdown ----------------------------------------------------

    def drain(self) -> None:
        """Graceful: reject new requests (retriable), finish in-flight."""
        if self._drain_started is not None:
            return
        self._drain_started = self.clock.now()
        self._feed({"ev": "drain", "t": self._drain_started})
        if self.listener is not None:
            try:
                self.sel.unregister(self.listener)
            except (KeyError, ValueError):
                pass
            self.listener.close()
            self.listener = None

    def shutdown(self) -> None:
        if self.stopped:
            return
        self.stopped = True
        now = self.clock.now()
        bye = frames.encode_frame({"kind": frames.BYE}, self.codec)
        for wid, conn in list(self.worker_conn.items()):
            try:
                conn.sock.send(bye)
            except OSError:
                pass
        # flush best-effort, then close everything
        deadline = time.monotonic() + 0.5
        while any(c.out for c in self.conns.values()) and \
                time.monotonic() < deadline:
            for conn in list(self.conns.values()):
                self._flush(conn)
        for conn in list(self.conns.values()):
            self._close_conn(conn, now)
        if self.listener is not None:
            self.listener.close()
            self.listener = None
        for wid, proc in self.procs.items():
            if proc.poll() is None:
                proc.terminate()
        for wid, proc in self.procs.items():
            try:
                proc.wait(timeout=3.0)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=3.0)
        self.sel.close()

    # -- convenience ---------------------------------------------------------

    def wait_workers(self, timeout: float = 60.0) -> bool:
        """Serve until every worker has connected and sent READY."""
        t_end = self.clock.now() + timeout

        def ready():
            return all(w.connected for w in self.core.workers) or \
                self.clock.now() > t_end
        self.serve(until=ready)
        return all(w.connected for w in self.core.workers)
