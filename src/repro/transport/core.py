"""The master's brain: a pure, event-driven state machine.

``MasterCore`` is the transport tier's policy engine — admission with
bounded queues and 429-style backpressure, PR 6 ``Router`` /
``HealthView`` / ``DegradeLadder`` reuse, per-attempt timeouts with
capped-backoff retries, the exact-key result/routing caches — written so
that *everything it decides is a function of the events it is handed*:

* every event carries its timestamp ``t``; the core NEVER reads a clock;
* timers are requested as actions (``("timer", t_at, event)``) and come
  back as ordinary events when the driver fires them;
* randomness does not exist here (wire-fault decisions happen in the
  driver's shim and are themselves seeded).

That purity is the record/replay contract's foundation: the live socket
driver records the exact event sequence it processed (timestamps, frame
facts, fault decisions), and the replay driver feeds the same sequence
into a fresh core — same events in, same outcomes out, byte-identical
``outcome_digest``.  The wall-clock drivers own wall-clock concerns
(sockets, subprocesses, partial reads); the core owns meaning.

Worker-facing protocol: workers execute singleton (B=1) requests at their
shape-bucket ceiling and return payloads trimmed to the request's ``k``
with an integrity checksum.  The master verifies the checksum (a corrupt
or truncated-but-parseable payload surfaces here) and emits
``serving.server.Outcome`` rows compatible with every existing summary /
parity / digest tool.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.serving import admission as adm
from repro.serving import faults as flt
from repro.serving import health as hlt
from repro.serving import server as srv
from repro.serving.batcher import ShapeBucket, bucket_of
from repro.serving.queue import Request
from repro.serving.replica import WorkingSet
from repro.serving.router import RetryPolicy, Router
from repro.transport import frames
from repro.transport.cache import ResultCache, RouteMemo


@dataclass
class WorkerView:
    """What the master knows about one worker — observable facts only."""

    wid: int
    ws: WorkingSet
    connected: bool = False
    epoch: int = 0                       # bumps on every (re)connect
    inflight: dict[int, int] = field(default_factory=dict)  # aid -> rid

    # Router duck-typing (it scores pool entries by load + affinity)
    def load(self) -> int:
        return len(self.inflight)

    def affinity(self, cluster_ids: np.ndarray, now: float) -> float:
        return self.ws.score(cluster_ids, now)


@dataclass
class _Attempt:
    aid: int
    wid: int
    kind: str                   # "primary" | "retry" | "queued"
    brownout: bool
    sent_at: float
    dead: bool = False


@dataclass
class _Track:
    req: Request
    conn: int                   # client connection the reply goes to
    crid: int                   # client-side request id (echoed in replies)
    attempts: dict[int, _Attempt] = field(default_factory=dict)
    retries_used: int = 0
    queued: bool = False        # sitting in the bounded pending queue
    done: bool = False

    def live(self) -> list[_Attempt]:
        return [a for a in self.attempts.values() if not a.dead]

    def exclude(self) -> frozenset[int]:
        return frozenset(a.wid for a in self.attempts.values())

    def attempt_on(self, wid: int) -> _Attempt | None:
        mine = [a for a in self.attempts.values() if a.wid == wid]
        return max(mine, key=lambda a: a.aid) if mine else None


@dataclass(frozen=True)
class MasterConfig:
    """Everything the master's policy depends on (drivers add mechanism
    knobs — socket paths, reconnect backoff — on top)."""

    n_workers: int
    ceilings: tuple[int, ...]
    lane_depth: int = 4             # in-flight requests per worker (bound)
    max_pending: int = 64           # master-side wait queue (bound)
    hb_interval: float = 0.05
    miss_factor: float = 4.0
    anomaly_factor: float = 3.0
    top_c: int = 4
    ws_decay: float = 2.0
    cache_size: int = 0             # 0 = result cache off
    route_memo_size: int = 1024
    service_decay: float = 0.6
    service_cold: float = 0.02
    retry_after_s: float = 0.05     # suggested client backoff on REJECTED
    retry: RetryPolicy = RetryPolicy(relative=True, timeout_mult=6.0,
                                     max_retries=2, backoff_base=0.005,
                                     backoff_cap=0.1)
    ladder: adm.DegradeLadder | None = None

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"need >= 1 worker, got {self.n_workers}")
        if self.lane_depth < 1 or self.max_pending < 0:
            raise ValueError("lane_depth must be >= 1, max_pending >= 0")
        if not self.retry.relative:
            raise ValueError(
                "transport retries must use attempt-relative timeouts "
                "(RetryPolicy(relative=True)): dispatch is immediate, so "
                "deadline-anchored timeouts would let one dropped frame "
                "stall a request for its whole budget")


class MasterCore:
    """Event-driven master state machine (see module docstring)."""

    def __init__(self, cfg: MasterConfig, centroids: np.ndarray):
        self.cfg = cfg
        self.workers = [WorkerView(w, WorkingSet(decay=cfg.ws_decay))
                        for w in range(cfg.n_workers)]
        self.health = hlt.HealthView(
            cfg.n_workers, hb_interval=cfg.hb_interval,
            miss_factor=cfg.miss_factor, anomaly_factor=cfg.anomaly_factor)
        self.router = Router(self.workers, self.health, centroids,
                             top_c=cfg.top_c)
        self.service = adm.ServiceEMA(decay=cfg.service_decay,
                                      cold=cfg.service_cold)
        self.ladder = cfg.ladder or adm.DegradeLadder()
        self.results = ResultCache(cfg.cache_size) if cfg.cache_size else None
        self.route_memo = RouteMemo(cfg.route_memo_size)
        self.draining = False
        self.outcomes: dict[int, srv.Outcome] = {}
        self.assignments: list[tuple] = []   # (rid, aid, wid, kind, reason)
        self._tracks: dict[int, _Track] = {}
        self._pending: deque[int] = deque()  # rids waiting for a free slot
        self._rid = itertools.count()
        self._aid = itertools.count()
        self.stats = {k: 0 for k in (
            "offered", "dispatched", "retries_sent", "timeouts",
            "rejected_backpressure", "rejected_draining", "shed_expired",
            "cache_hits", "corrupt_detected", "late_ignored", "malformed",
            "worker_errors", "worker_lost", "respawns", "brownouts",
            "queued")}

    # -- helpers -------------------------------------------------------------

    def _bucket(self, req: Request) -> ShapeBucket:
        return bucket_of(req.k, req.n_probe, self.cfg.ceilings, 1)

    def start(self, t0: float) -> None:
        self.health.start(t0)

    def idle(self) -> bool:
        """No request is open — the drain-complete condition."""
        return not self._pending and \
            all(tr.done for tr in self._tracks.values())

    def open_requests(self) -> int:
        return sum(not tr.done for tr in self._tracks.values())

    def _available(self, wid: int, t: float) -> bool:
        w = self.workers[wid]
        return w.connected and len(w.inflight) < self.cfg.lane_depth and \
            self.health.status(wid, t) != hlt.DOWN

    def _load_factor(self, t: float) -> float:
        up = [w for w in self.workers if w.connected]
        if not up:
            return np.inf
        inflight = sum(len(w.inflight) for w in up)
        return (inflight + len(self._pending)) / \
            (len(up) * self.cfg.lane_depth)

    # -- event entry point ----------------------------------------------------

    def handle(self, ev: dict) -> list[tuple]:
        """Process one timestamped event; returns the driver's to-do list:
        ``("send", wid, frame)`` / ``("reply", conn, frame)`` /
        ``("timer", t_at, event)``.  Frames carry ndarrays; the driver
        packs them for the wire (the sim/replay drivers never do)."""
        kind = ev["ev"]
        t = ev["t"]
        if kind == "req":
            return self._on_req(ev, t)
        if kind == "resp":
            return self._on_resp(ev, t)
        if kind == "werr":
            return self._on_werr(ev, t)
        if kind == "hb":
            wid = ev["wid"]
            if self.workers[wid].connected:
                self.health.beat(wid, t)
            return []
        if kind == "timeout":
            return self._on_timeout(ev["rid"], ev["aid"], t)
        if kind == "retry":
            return self._on_retry(ev["rid"], t)
        if kind == "expire":
            return self._on_expire(ev["rid"], t)
        if kind == "lost":
            return self._on_lost(ev["wid"], t)
        if kind == "up":
            return self._on_up(ev, t)
        if kind == "drain":
            self.draining = True
            return []
        raise ValueError(f"unknown event kind {kind!r}")

    # -- request intake -------------------------------------------------------

    def _reject(self, req: Request, track_conn: int, crid: int, t: float,
                reason: str) -> list[tuple]:
        self.stats[f"rejected_{reason}"] += 1
        self.outcomes[req.rid] = srv.Outcome(
            request=req, status=srv.REJECTED, bucket=None, ids=None,
            dists=None, t_done=t, k_effective=0)
        return [("reply", track_conn,
                 {"kind": frames.RETRY_AFTER, "rid": crid,
                  "delay_s": self.cfg.retry_after_s, "reason": reason})]

    def _on_req(self, ev: dict, t: float) -> list[tuple]:
        conn, crid = ev["conn"], ev["crid"]
        try:
            rid = next(self._rid)
            req = Request(rid=rid, q=np.asarray(ev["q"]), k=int(ev["k"]),
                          n_probe=int(ev["n_probe"]), arrival=t,
                          deadline=t + float(ev["deadline_s"]))
        except (ValueError, TypeError, KeyError) as e:
            self.stats["malformed"] += 1
            return [("reply", conn,
                     {"kind": frames.ERR, "rid": crid,
                      "code": "bad_request", "detail": str(e)})]
        self.stats["offered"] += 1
        if self.draining:
            return self._reject(req, conn, crid, t, "draining")
        req = req.k_capped(self.cfg.ceilings[-1])
        req = self.ladder.apply(req, self._load_factor(t))
        track = _Track(req=req, conn=conn, crid=crid)
        self._tracks[rid] = track
        if self.results is not None:
            hit = self.results.get(req.q, req.k, req.n_probe)
            if hit is not None:
                self.stats["cache_hits"] += 1
                track.done = True
                dists, ids = hit
                return self._complete(track, dists, ids, wid=None, t=t,
                                      cached=True)
        acts = self._dispatch(track, t, kind="primary")
        if acts is None:
            return self._enqueue(track, t)
        return acts

    def _enqueue(self, track: _Track, t: float) -> list[tuple]:
        """No worker has a free slot: bounded wait queue or 429."""
        if len(self._pending) >= self.cfg.max_pending:
            reason = "backpressure"
            track.done = True
            return self._reject(track.req, track.conn, track.crid, t, reason)
        self._pending.append(track.req.rid)
        track.queued = True
        self.stats["queued"] += 1
        # the queue's only exit guarantees: a slot frees (dispatch below)
        # or the deadline passes (this timer -> SHED)
        return [("timer", track.req.deadline,
                 {"ev": "expire", "rid": track.req.rid})]

    # -- dispatch -------------------------------------------------------------

    def _dispatch(self, track: _Track, t: float,
                  kind: str) -> list[tuple] | None:
        """Route + send one attempt; None when no available worker (caller
        queues or fails)."""
        req = track.req
        tried = set(track.exclude()) if kind != "primary" else set()
        chosen, reason, brownout = None, "", False
        hint = self.route_memo.get(req.q) if kind == "primary" else None
        if hint is not None and hint not in tried and \
                self._available(hint, t):
            chosen, reason = hint, "cache-route"
        while chosen is None:
            decision = self.router.route(req, t, frozenset(tried))
            if decision is None:
                return None
            if self._available(decision.replica, t):
                chosen = decision.replica
                reason, brownout = decision.reason, decision.brownout
                break
            if decision.replica in tried:
                return None     # route's relax-exclude fallback repeated
            tried.add(decision.replica)
            if len(tried) >= self.cfg.n_workers:
                return None
        aid = next(self._aid)
        track.attempts[aid] = _Attempt(aid=aid, wid=chosen, kind=kind,
                                       brownout=brownout, sent_at=t)
        self.workers[chosen].inflight[aid] = req.rid
        self.assignments.append((req.rid, aid, chosen, kind, reason))
        self.stats["dispatched"] += 1
        if brownout:
            self.stats["brownouts"] += 1
        est = self.service.estimate(self._bucket(req))
        return [
            ("send", chosen, {"kind": frames.REQ, "rid": req.rid,
                              "q": req.q, "k": req.k,
                              "n_probe": req.n_probe}),
            ("timer", self.cfg.retry.timeout_at(t, req.deadline, est),
             {"ev": "timeout", "rid": req.rid, "aid": aid}),
        ]

    def _drain_pending(self, t: float) -> list[tuple]:
        """A slot freed (response, reconnect): dispatch waiting requests."""
        acts: list[tuple] = []
        while self._pending:
            rid = self._pending[0]
            track = self._tracks.get(rid)
            if track is None or track.done:
                self._pending.popleft()
                continue
            sub = self._dispatch(track, t, kind="queued")
            if sub is None:
                break
            self._pending.popleft()
            track.queued = False
            acts.extend(sub)
        return acts

    # -- completion paths -----------------------------------------------------

    def _complete(self, track: _Track, dists: np.ndarray, ids: np.ndarray,
                  wid: int | None, t: float,
                  cached: bool = False) -> list[tuple]:
        req = track.req
        att = track.attempt_on(wid) if wid is not None else None
        brownout = bool(att.brownout) if att is not None else False
        status = srv.DEGRADED if (req.degraded or brownout) else srv.OK
        self.outcomes[req.rid] = srv.Outcome(
            request=req, status=status, bucket=self._bucket(req),
            ids=np.asarray(ids).copy(), dists=np.asarray(dists).copy(),
            t_done=t, k_effective=req.k, replica=wid,
            retries=track.retries_used)
        for other in track.live():      # late twins are ignored, not retried
            other.dead = True
        return [("reply", track.conn,
                 {"kind": frames.RESP, "rid": track.crid, "status": status,
                  "k": req.k, "dists": np.asarray(dists),
                  "ids": np.asarray(ids), "cached": cached})]

    def _terminal(self, track: _Track, status: str, t: float,
                  code: str) -> list[tuple]:
        track.done = True
        req = track.req
        self.outcomes[req.rid] = srv.Outcome(
            request=req, status=status, bucket=None, ids=None, dists=None,
            t_done=t, k_effective=0, retries=track.retries_used)
        return [("reply", track.conn,
                 {"kind": frames.ERR, "rid": track.crid, "code": code,
                  "detail": f"request {req.rid} terminated {status}"})]

    def _on_resp(self, ev: dict, t: float) -> list[tuple]:
        wid, rid = ev["wid"], ev["rid"]
        w = self.workers[wid]
        self.health.beat(wid, t)
        track = self._tracks.get(rid)
        att = track.attempt_on(wid) if track is not None else None
        if att is not None:
            w.inflight.pop(att.aid, None)
        else:                           # duplicate delivery / pre-lost aid
            for aid, r in list(w.inflight.items()):
                if r == rid:
                    del w.inflight[aid]
                    break
        acts: list[tuple] = []
        if track is None or track.done:
            self.stats["late_ignored"] += 1
            return self._drain_pending(t)
        dists = np.asarray(ev["dists"])
        ids = np.asarray(ev["ids"])
        if flt.payload_checksum(dists, ids) != int(ev["checksum"]) or \
                len(ids) != track.req.k:
            self.stats["corrupt_detected"] += 1
            if att is not None:
                att.dead = True
            if not track.live():
                acts.extend(self._retry_or_fail(track, t))
            acts.extend(self._drain_pending(t))
            return acts
        if att is not None:
            bucket = self._bucket(track.req)
            est = self.service.estimate(bucket)
            dt = t - att.sent_at
            self.service.observe(bucket, dt)
            self.health.observe(wid, dt, baseline=est)
        track.done = True
        if self.results is not None:
            self.results.put(track.req.q, track.req.k, track.req.n_probe,
                             dists, ids)
        self.route_memo.put(track.req.q, wid)
        w.ws.note(self.router.top_centroids(track.req.q), t)
        acts.extend(self._complete(track, dists, ids, wid, t))
        acts.extend(self._drain_pending(t))
        return acts

    # -- failure paths --------------------------------------------------------

    def _retry_or_fail(self, track: _Track, t: float) -> list[tuple]:
        if track.done:
            return []
        if track.retries_used >= self.cfg.retry.max_retries:
            return self._terminal(track, srv.FAILED, t, code="failed")
        track.retries_used += 1
        return [("timer", t + self.cfg.retry.backoff(track.retries_used),
                 {"ev": "retry", "rid": track.req.rid})]

    def _on_timeout(self, rid: int, aid: int, t: float) -> list[tuple]:
        track = self._tracks.get(rid)
        if track is None or track.done:
            return []
        att = track.attempts.get(aid)
        if att is None or att.dead:
            return []
        att.dead = True
        self.stats["timeouts"] += 1
        self.workers[att.wid].inflight.pop(aid, None)
        acts: list[tuple] = []
        if not track.live():
            acts.extend(self._retry_or_fail(track, t))
        acts.extend(self._drain_pending(t))
        return acts

    def _on_retry(self, rid: int, t: float) -> list[tuple]:
        track = self._tracks.get(rid)
        if track is None or track.done:
            return []
        self.stats["retries_sent"] += 1
        acts = self._dispatch(track, t, kind="retry")
        if acts is None:
            return self._enqueue(track, t)
        return acts

    def _on_expire(self, rid: int, t: float) -> list[tuple]:
        track = self._tracks.get(rid)
        if track is None or track.done or not track.queued:
            return []
        track.queued = False
        try:
            self._pending.remove(rid)
        except ValueError:
            pass
        self.stats["shed_expired"] += 1
        return self._terminal(track, srv.SHED, t, code="shed")

    def _on_werr(self, ev: dict, t: float) -> list[tuple]:
        wid, rid = ev["wid"], ev["rid"]
        self.stats["worker_errors"] += 1
        self.health.beat(wid, t)        # an error reply is still liveness
        track = self._tracks.get(rid)
        att = track.attempt_on(wid) if track is not None else None
        if att is not None:
            self.workers[wid].inflight.pop(att.aid, None)
            att.dead = True
        acts: list[tuple] = []
        if track is not None and not track.done and not track.live():
            acts.extend(self._retry_or_fail(track, t))
        acts.extend(self._drain_pending(t))
        return acts

    # -- worker lifecycle -----------------------------------------------------

    def _on_lost(self, wid: int, t: float) -> list[tuple]:
        w = self.workers[wid]
        w.connected = False
        self.stats["worker_lost"] += 1
        acts: list[tuple] = []
        for aid in sorted(w.inflight):
            rid = w.inflight[aid]
            track = self._tracks.get(rid)
            if track is None:
                continue
            att = track.attempts.get(aid)
            if att is not None:
                att.dead = True
            if not track.done and not track.live():
                acts.extend(self._retry_or_fail(track, t))
        w.inflight.clear()
        return acts

    def _on_up(self, ev: dict, t: float) -> list[tuple]:
        wid = ev["wid"]
        w = self.workers[wid]
        w.connected = True
        w.epoch += 1
        w.inflight.clear()
        self.health.reset(wid, t)
        if ev.get("respawned"):
            self.stats["respawns"] += 1
            w.ws.reset(t)
        # seed the service EMA from the worker's measured warmup times, so
        # the first attempt timeouts are sized from evidence, not the cold
        # default (the ready frame carries {"k,n_probe": seconds})
        for key, dt in sorted((ev.get("svc") or {}).items()):
            k_s, np_s = str(key).split(",")
            self.service.observe(
                ShapeBucket(k=int(k_s), batch=1, n_probe=int(np_s)),
                float(dt))
        return self._drain_pending(t)

    # -- reporting ------------------------------------------------------------

    def outcome_list(self) -> list[srv.Outcome]:
        return [self.outcomes[rid] for rid in sorted(self.outcomes)]

    def cache_stats(self) -> dict:
        return {"results": self.results.stats() if self.results else None,
                "route_memo": self.route_memo.stats()}
