"""Spec-built engine host: the one place a (dataset, index) pair is
constructed from a declarative spec.

The transport tier needs the *same* engine in three different processes:
worker subprocesses (live serving), the replay driver (re-executing
recorded responses), and the bench's direct-call parity baseline.  All
three build from one JSON-able spec through this module, so "the same
engine" is a guarantee by construction — same seeds, same k-means
iterations, same PQ codebooks — and the record/replay checksum contract
(a replayed response must reproduce the recorded payload checksum
bit-for-bit) is checking cross-process engine determinism, not hoping
for it.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import search as idx_search
from repro.serving.batcher import ShapeBucket, bucket_of, k_ceilings
from repro.serving.server import trim_topk
from repro.serving.state import ServingState


def build_spec(*, n: int = 4096, d: int = 32, seed: int = 0,
               ks=(10, 100, 1000), n_probe: int = 8,
               data: str = "clustered", n_clusters: int | None = None,
               n_bits: int = 4, n_iter: int = 6,
               use_bbc: bool = True) -> dict:
    """A fully-determined, JSON-able engine description."""
    if data not in ("clustered", "isotropic", "manifold"):
        raise ValueError(f"unknown dataset kind {data!r}")
    return {"n": int(n), "d": int(d), "seed": int(seed),
            "ks": [int(k) for k in ks], "n_probe": int(n_probe),
            "data": data,
            "n_clusters": int(n_clusters or max(int(np.sqrt(n)), 16)),
            "n_bits": int(n_bits), "n_iter": int(n_iter),
            "use_bbc": bool(use_bbc)}


def make_dataset(spec: dict) -> np.ndarray:
    rng = np.random.default_rng(int(spec["seed"]))
    kind = spec.get("data", "clustered")
    n, d = int(spec["n"]), int(spec["d"])
    if kind == "clustered":
        return synthetic.clustered(rng, n, d)
    if kind == "isotropic":
        return synthetic.isotropic(rng, n, d)
    return synthetic.manifold(rng, n, d)


def build_state_from_spec(spec: dict) -> tuple[ServingState, tuple[int, ...]]:
    """Spec -> (ServingState, k ceilings).  Deterministic: every process
    handed the same spec builds a bit-identical engine."""
    x = jnp.asarray(make_dataset(spec))
    index = idx_search.build_pq_index(
        jax.random.key(int(spec["seed"])), x, int(spec["n_clusters"]),
        n_bits=int(spec["n_bits"]), n_iter=int(spec["n_iter"]))
    state = ServingState(index, use_bbc=bool(spec.get("use_bbc", True)))
    return state, k_ceilings(spec["ks"])


def make_exec_fn(state: ServingState, ceilings: tuple[int, ...]):
    """Singleton executor: run a (d,) query at its bucket ceiling, trim to
    the requested k.  This is the worker's hot path AND the replay /
    parity baseline — one definition, three processes."""
    def exec_fn(q: np.ndarray, k: int,
                n_probe: int) -> tuple[np.ndarray, np.ndarray]:
        bucket = bucket_of(int(k), int(n_probe), ceilings, 1)
        res = state.engine(bucket).search(jnp.asarray(q))
        jax.block_until_ready((res.dists, res.ids))
        return trim_topk(np.asarray(res.dists), np.asarray(res.ids), int(k))
    return exec_fn


def warmup_and_measure(exec_fn, spec: dict,
                       ceilings: tuple[int, ...]) -> dict[str, float]:
    """Compile every serving bucket and measure post-compile singleton
    service times — the ``{"k,n_probe": seconds}`` map a worker's READY
    frame carries so the master's service EMA starts from evidence."""
    rng = np.random.default_rng(int(spec["seed"]) + 1)
    q = rng.standard_normal(int(spec["d"])).astype(np.float32)
    n_probe = int(spec["n_probe"])
    svc: dict[str, float] = {}
    for k in ceilings:
        exec_fn(q, k, n_probe)                  # compile
        t0 = time.perf_counter()
        exec_fn(q, k, n_probe)                  # measure warm
        svc[f"{k},{n_probe}"] = time.perf_counter() - t0
    return svc


def service_fn_from_svc(svc: dict[str, float], default: float = 0.005):
    """The sim-facing inverse of a READY frame's svc map."""
    table = {tuple(int(s) for s in key.split(",")): float(dt)
             for key, dt in svc.items()}

    def service_fn(bucket: ShapeBucket) -> float:
        return table.get((bucket.k, bucket.n_probe), default)
    return service_fn
