"""Record/replay transcript + the seeded wire-fault shim's bookkeeping.

The replay contract: a live socket run is fully described by the ordered
sequence of *core events* its driver processed — request arrivals,
response facts, heartbeats, timer firings, connection losses — each with
the wall timestamp it was handled at.  ``MasterCore`` is pure over that
sequence, so feeding the recorded events into a fresh core reproduces
every decision, every outcome, and the exact ``outcome_digest``.

What the transcript does NOT store is response payloads: a ``resp`` entry
keeps only the integrity checksum (plus rid/wid/k facts).  Replay
re-executes each response through the in-process engine and verifies the
recorded checksum — so digest equality is a genuine end-to-end
determinism check on the worker's wire bytes (same spec-built engine in a
different process produced the same payload), not a tautology of copying
payloads around.

Wire-fault decisions are recorded as informational ``fault`` entries:
replay never re-decides faults (their *consequences* — the dropped frame
that never became an event, the delayed delivery timestamp — are already
baked into the event sequence), but the entries document what the run was
subjected to and let tests assert the schedule actually fired.

Format: JSON lines — one header object, then one object per entry.
ndarrays (request vectors) are stored as dtype + shape + base64 bytes and
round-trip bit-exactly.
"""
from __future__ import annotations

import base64
import json
from typing import Any, Iterable

import numpy as np

from repro.serving.faults import WireDecision, WireSchedule

# core-event kinds replay feeds back into MasterCore; anything else in a
# transcript ("fault", "end") is documentation
CORE_EVENTS = ("req", "resp", "werr", "hb", "timeout", "retry", "expire",
               "lost", "up", "drain")


def _ser(obj: Any) -> Any:
    if isinstance(obj, np.ndarray):
        arr = np.ascontiguousarray(obj)
        return {"__nd__": {"dtype": arr.dtype.name,
                           "shape": list(arr.shape),
                           "b64": base64.b64encode(arr.tobytes()).decode()}}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, dict):
        return {k: _ser(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_ser(v) for v in obj]
    return obj


def _deser(obj: Any) -> Any:
    if isinstance(obj, dict):
        nd = obj.get("__nd__")
        if nd is not None and set(obj) == {"__nd__"}:
            return np.frombuffer(
                base64.b64decode(nd["b64"]),
                dtype=np.dtype(nd["dtype"])).reshape(nd["shape"])
        return {k: _deser(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_deser(v) for v in obj]
    return obj


class Transcript:
    """Ordered record of one live run (header + entries)."""

    def __init__(self, header: dict | None = None):
        self.header = dict(header or {})
        self.entries: list[dict] = []

    def __len__(self) -> int:
        return len(self.entries)

    def append(self, entry: dict) -> None:
        """Record one entry.  ``resp`` entries are stripped of their
        payload arrays here (see module docstring) — recording is the one
        place the stripping rule lives."""
        if entry.get("ev") == "resp":
            entry = {k: v for k, v in entry.items()
                     if k not in ("dists", "ids")}
        self.entries.append(entry)

    def core_events(self) -> Iterable[dict]:
        return (e for e in self.entries if e.get("ev") in CORE_EVENTS)

    def fault_entries(self) -> list[dict]:
        return [e for e in self.entries if e.get("ev") == "fault"]

    # -- persistence ---------------------------------------------------------

    def dumps(self) -> str:
        lines = [json.dumps(_ser(self.header), sort_keys=True)]
        lines.extend(json.dumps(_ser(e), sort_keys=True)
                     for e in self.entries)
        return "\n".join(lines) + "\n"

    @classmethod
    def loads(cls, text: str) -> "Transcript":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty transcript")
        t = cls(header=_deser(json.loads(lines[0])))
        t.entries = [_deser(json.loads(ln)) for ln in lines[1:]]
        return t

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def load(cls, path: str) -> "Transcript":
        with open(path) as f:
            return cls.loads(f.read())


class WireShim:
    """Per-link frame counters over a :class:`WireSchedule`.

    The schedule's decisions are keyed by the per-(worker, direction)
    frame sequence number; this object owns those counters so every frame
    crossing the shim consumes exactly one decision — the invariant that
    makes live runs reproducible under timing jitter.  A ``None`` schedule
    is the fault-free shim (every decision is clean delivery)."""

    def __init__(self, schedule: WireSchedule | None = None):
        self.schedule = schedule
        self._seq: dict[tuple[int, str], int] = {}
        self.decisions: list[tuple[int, str, int, str, float]] = []

    def decide(self, wid: int, direction: str) -> WireDecision:
        seq = self._seq.get((wid, direction), 0)
        self._seq[(wid, direction)] = seq + 1
        if self.schedule is None:
            return WireDecision()
        d = self.schedule.decide(wid, direction, seq)
        if d.kind is not None:
            self.decisions.append((wid, direction, seq, d.kind, d.delay))
        return d

    def fault_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for _, _, _, kind, _ in self.decisions:
            out[kind] = out.get(kind, 0) + 1
        return out
