"""Framed client: connect, pace a trace open-loop, collect typed replies.

``NetClient`` is the bench's and the tests' view of the serving front
door.  ``run_trace`` sends requests at their trace arrival offsets
(open-loop — a slow server does NOT slow the offered load, which is what
makes the backpressure path real) while draining replies concurrently,
and returns one record per request: completed payloads with client-side
latency, ``retry_after`` rejections with their suggested delay, and typed
errors.  Nothing here retries — the master already owns retries against
workers; client-side retry policy belongs to real applications, and the
bench wants to SEE rejections, not paper over them.
"""
from __future__ import annotations

import select
import socket
import time

import numpy as np

from repro.transport import frames
from repro.transport.worker import connect_addr


class NetClient:
    def __init__(self, addr: dict, codec: str | None = None,
                 timeout: float = 10.0):
        self.addr = addr
        self.codec = codec or frames.default_codec()
        self.timeout = float(timeout)
        self.sock: socket.socket | None = None
        self.reader = frames.FrameReader()
        self._queued: list[dict] = []
        self._eof = False

    def connect(self) -> "NetClient":
        self.sock = connect_addr(self.addr, timeout=self.timeout)
        self.sock.sendall(frames.encode_frame(
            {"kind": frames.HELLO, "role": "client"}, self.codec))
        return self

    def close(self) -> None:
        if self.sock is not None:
            try:
                self.sock.sendall(frames.encode_frame(
                    {"kind": frames.BYE}, self.codec))
            except OSError:
                pass
            self.sock.close()
            self.sock = None

    def __enter__(self) -> "NetClient":
        return self.connect()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- low level -----------------------------------------------------------

    def send_raw(self, data: bytes) -> None:
        """Test hook: put arbitrary bytes on the wire (fuzzing)."""
        self.sock.sendall(data)

    def send_request(self, rid: int, q: np.ndarray, k: int, n_probe: int,
                     deadline_s: float) -> None:
        self.sock.sendall(frames.encode_frame(
            {"kind": frames.REQ, "rid": int(rid),
             "q": frames.pack_array(np.ascontiguousarray(q)),
             "k": int(k), "n_probe": int(n_probe),
             "deadline_s": float(deadline_s)}, self.codec))

    def _drain(self, wait: float) -> list[dict]:
        """Read whatever arrives within ``wait`` seconds (may be [])."""
        out: list[dict] = []
        if self._eof:
            raise ConnectionError("server closed the connection")
        end = time.monotonic() + max(wait, 0.0)
        while True:
            remaining = end - time.monotonic()
            r, _, _ = select.select([self.sock], [], [], max(remaining, 0.0))
            if not r:
                return out
            data = self.sock.recv(262144)
            if not data:
                # frames parsed just before the close must not be lost —
                # a typed error followed by EOF is the bad_frame contract
                self._eof = True
                if out:
                    return out
                raise ConnectionError("server closed the connection")
            out.extend(self.reader.feed(data))
            # return as soon as a whole frame is ready: callers poll in a
            # loop, and holding a parsed reply for the rest of the window
            # would add the full window to every round trip
            if out or remaining <= 0:
                return out

    def recv_reply(self, timeout: float | None = None) -> dict | None:
        """Block for one frame (or until ``timeout``)."""
        if self._queued:
            return self._queued.pop(0)
        end = time.monotonic() + (timeout if timeout is not None
                                  else self.timeout)
        while True:
            got = self._drain(end - time.monotonic())
            if got:
                self._queued.extend(got[1:])
                return got[0]
            if time.monotonic() >= end:
                return None

    # -- trace driving -------------------------------------------------------

    def run_trace(self, trace, *, settle: float = 15.0) -> dict[int, dict]:
        """Open-loop paced send of a ``serving.queue`` Request trace.

        Returns ``{rid: record}`` where record is one of::

            {"status": "ok"|"degraded", "ids", "dists", "cached",
             "latency_s"}
            {"status": "rejected", "delay_s", "reason"}
            {"status": "error", "code", "detail"}
        """
        trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        t_base = trace[0].arrival if trace else 0.0
        records: dict[int, dict] = {}
        sent_at: dict[int, float] = {}
        start = time.monotonic()

        def handle(frame: dict) -> None:
            rid = frame.get("rid")
            kind = frame.get("kind")
            now = time.monotonic()
            if kind == frames.RESP:
                records[rid] = {
                    "status": str(frame.get("status", "ok")),
                    "ids": frames.unpack_array(frame["ids"]),
                    "dists": frames.unpack_array(frame["dists"]),
                    "cached": bool(frame.get("cached", False)),
                    "latency_s": now - sent_at.get(rid, start)}
            elif kind == frames.RETRY_AFTER:
                records[rid] = {"status": "rejected",
                                "delay_s": float(frame.get("delay_s", 0.0)),
                                "reason": str(frame.get("reason", ""))}
            elif kind == frames.ERR:
                records[rid] = {"status": "error",
                                "code": str(frame.get("code", "unknown")),
                                "detail": str(frame.get("detail", ""))}

        for req in trace:
            target = start + (req.arrival - t_base)
            while True:
                wait = target - time.monotonic()
                if wait <= 0:
                    break
                for frame in self._drain(min(wait, 0.05)):
                    handle(frame)
            sent_at[req.rid] = time.monotonic()
            self.send_request(req.rid, req.q, req.k, req.n_probe,
                              req.deadline - req.arrival)
        end = time.monotonic() + settle
        while len(records) < len(trace) and time.monotonic() < end:
            for frame in self._drain(0.1):
                handle(frame)
        return records
