"""Exact-key LRU caches for the Zipf head of the query stream.

Real ANN query streams are head-heavy (rank-frequency roughly Zipf — the
workload analyses PAPERS.md cites), so a small exact-key cache in the
master absorbs the hottest queries without touching a worker.  Two caches
share one LRU core:

* :class:`ResultCache` — ``(query bytes, k, n_probe) -> (dists, ids)``.
  Exact-key only: a hit returns the byte-identical payload a worker
  produced earlier for the same request parameters, so cached results are
  correct *by construction* — no approximate matching, no staleness model
  beyond the generation tag (the cache is flushed on engine swaps).
* :class:`RouteMemo` — ``query bytes -> worker id``: a routing hint that
  sends a repeated query back to the worker whose caches and predictor
  are already warm for it, complementing the centroid-affinity router
  with zero geometry work on the hot path.

Both live inside :class:`~repro.transport.core.MasterCore` and mutate only
on core events, so a replayed event stream reproduces the exact same
hit/miss sequence — cache state never needs recording.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable

import numpy as np


class LruCache:
    """Bounded mapping with least-recently-used eviction (get refreshes)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._d: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d

    def get(self, key: Hashable) -> Any | None:
        try:
            self._d.move_to_end(key)
        except KeyError:
            self.misses += 1
            return None
        self.hits += 1
        return self._d[key]

    def put(self, key: Hashable, value: Any) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._d.clear()

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"size": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": round(self.hits / total, 4) if total else 0.0}


def result_key(q: np.ndarray, k: int, n_probe: int) -> tuple:
    """Exact-key identity of one request's results: the query's raw bytes
    (bit-exact — two queries differing in the last mantissa bit are
    different keys) plus the retrieval parameters that shape the answer."""
    arr = np.ascontiguousarray(q)
    return (arr.tobytes(), arr.dtype.name, int(k), int(n_probe))


class ResultCache:
    """LRU of completed result payloads, keyed by :func:`result_key`."""

    def __init__(self, capacity: int = 256):
        self._lru = LruCache(capacity)

    def get(self, q: np.ndarray, k: int,
            n_probe: int) -> tuple[np.ndarray, np.ndarray] | None:
        return self._lru.get(result_key(q, k, n_probe))

    def put(self, q: np.ndarray, k: int, n_probe: int,
            dists: np.ndarray, ids: np.ndarray) -> None:
        # copies: cached payloads must be immune to caller-side mutation
        self._lru.put(result_key(q, k, n_probe),
                      (np.array(dists, copy=True), np.array(ids, copy=True)))

    def clear(self) -> None:
        self._lru.clear()

    def stats(self) -> dict:
        return self._lru.stats()


class RouteMemo:
    """LRU routing hint: last worker that served each exact query."""

    def __init__(self, capacity: int = 1024):
        self._lru = LruCache(capacity)

    def get(self, q: np.ndarray) -> int | None:
        arr = np.ascontiguousarray(q)
        return self._lru.get((arr.tobytes(), arr.dtype.name))

    def put(self, q: np.ndarray, wid: int) -> None:
        arr = np.ascontiguousarray(q)
        self._lru.put((arr.tobytes(), arr.dtype.name), int(wid))

    def stats(self) -> dict:
        return self._lru.stats()
