"""Multi-process serving front-end: real sockets under the PR 6 brain.

The discrete-event tier (``repro.serving``) owns the serving *policy* —
admission, routing, health, retries, degradation.  This package owns the
*mechanism*: a master process speaking length-prefixed msgpack-or-JSON
frames over TCP / Unix sockets to N worker subprocesses, with bounded
queues and explicit backpressure, per-connection timeouts, capped-backoff
reconnects, heartbeats over the real wire, worker respawn, a seeded wire-
fault shim, and a record/replay transcript that keeps ``outcome_digest``
byte-identical between a live socket run and its in-process replay.

Layering (each module usable without the ones after it):

* ``frames``  — wire format: length-prefixed frames, codecs, array packing
* ``cache``   — exact-key LRU result + routing caches (the Zipf head)
* ``core``    — :class:`MasterCore`, the pure event-driven master state
  machine (never reads a clock; all decisions from event timestamps)
* ``wire``    — the transcript format + shim bookkeeping shared by the
  live driver, the simulator, and replay
* ``sim``     — a virtual-clock loopback driver over ``MasterCore`` for
  deterministic fuzz / property tests (no processes, no sockets)
* ``worker``  — the worker subprocess: spec-built engine behind a framed
  request loop (``python -m repro.transport.worker``)
* ``master``  — the live socket driver: selectors loop, supervisor,
  fault shim, recording
* ``replay``  — feed a recorded transcript back through ``MasterCore``
  with payload re-execution + checksum verification
* ``client``  — a small framed client used by benches, tests, and
  ``launch/serve.py --mode net``
"""
from repro.transport.cache import LruCache, ResultCache     # noqa: F401
from repro.transport.core import MasterCore, MasterConfig   # noqa: F401
from repro.transport.frames import (FrameError, FrameReader,  # noqa: F401
                                    encode_frame, pack_array,
                                    unpack_array)
from repro.transport.replay import (ReplayError,            # noqa: F401
                                    replay_transcript)
from repro.transport.sim import LoopbackSim                 # noqa: F401
from repro.transport.wire import Transcript, WireShim       # noqa: F401

# enginehost / worker / master / client import jax and sockets; they are
# imported explicitly by their users so this package stays light

