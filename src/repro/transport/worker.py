"""Worker subprocess: one spec-built engine behind a framed socket loop.

``python -m repro.transport.worker <spec.json>`` builds the engine
described by the spec (see :mod:`repro.transport.enginehost`), warms up
every serving bucket while measuring service times, then DIALS the master
and serves singleton requests until told to stop:

* the worker owns the reconnect loop — capped exponential backoff, fresh
  HELLO/READY handshake on every (re)connect, so a master-side disconnect
  fault or restart heals without supervisor involvement;
* READY carries the measured ``{"k,n_probe": seconds}`` warmup times, so
  the master's service EMA (and therefore its first attempt timeouts) is
  seeded from evidence the moment the worker joins;
* heartbeats go out every ``hb_interval`` over the same wire as data —
  a stalled or partitioned worker stops beating and the master's
  ``HealthView`` sees it;
* the request boundary never kills the process: malformed frames get a
  typed ``err`` reply (or, when the stream itself is corrupt, a clean
  reconnect), engine exceptions get ``err`` with code ``exec_error``.

SIGTERM sends a best-effort ``bye`` and exits 0 (the master's drain
path); a ``bye`` from the master does the same.
"""
from __future__ import annotations

import json
import os
import signal
import socket
import sys
import time

import numpy as np

from repro.serving import faults as flt
from repro.transport import frames
from repro.transport.enginehost import (build_state_from_spec, make_exec_fn,
                                        warmup_and_measure)


def connect_addr(addr: dict, timeout: float = 2.0) -> socket.socket:
    if addr["family"] == "unix":
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.settimeout(timeout)
        s.connect(addr["path"])
    else:
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.settimeout(timeout)
        s.connect((addr["host"], int(addr["port"])))
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


class WorkerApp:
    """The serve loop, separated from ``main`` for in-test reuse."""

    def __init__(self, spec: dict):
        self.spec = dict(spec)
        self.wid = int(spec["wid"])
        self.addr = spec["addr"]
        self.codec = spec.get("codec") or frames.default_codec()
        self.hb_interval = float(spec.get("hb_interval", 0.05))
        self.reconnect_base = float(spec.get("reconnect_base", 0.05))
        self.reconnect_cap = float(spec.get("reconnect_cap", 1.0))
        self.max_dials = int(spec.get("max_dials", 0))   # 0 = keep trying
        self.stop = False
        state, self.ceilings = build_state_from_spec(spec["engine"])
        self.exec_fn = make_exec_fn(state, self.ceilings)
        self.svc = warmup_and_measure(self.exec_fn, spec["engine"],
                                      self.ceilings)
        self.served = 0

    # -- one request ---------------------------------------------------------

    def _handle_req(self, frame: dict) -> dict:
        """REQ -> RESP/ERR frame.  Every failure is a typed reply; nothing
        a client or master sends can raise out of here."""
        rid = frame.get("rid")
        if not isinstance(rid, int):
            return {"kind": frames.ERR, "rid": -1, "wid": self.wid,
                    "code": "bad_request", "detail": "missing int rid"}
        try:
            q = frames.unpack_array(frame.get("q"))
            k = int(frame["k"])
            n_probe = int(frame["n_probe"])
            if q.ndim != 1:
                raise frames.FrameError(f"query must be 1-D, got {q.shape}")
            if not (0 < k <= self.ceilings[-1]):
                raise frames.FrameError(f"k={k} outside (0, "
                                        f"{self.ceilings[-1]}]")
            if not np.all(np.isfinite(np.asarray(q, dtype=np.float64))):
                raise frames.FrameError("query has non-finite values")
        except (frames.FrameError, KeyError, TypeError, ValueError) as e:
            return {"kind": frames.ERR, "rid": rid, "wid": self.wid,
                    "code": "bad_request", "detail": str(e)}
        try:
            dists, ids = self.exec_fn(q, k, n_probe)
        except Exception as e:          # engine bug: reply, don't die
            return {"kind": frames.ERR, "rid": rid, "wid": self.wid,
                    "code": "exec_error",
                    "detail": f"{type(e).__name__}: {e}"}
        self.served += 1
        return {"kind": frames.RESP, "rid": rid, "wid": self.wid,
                "dists": frames.pack_array(dists),
                "ids": frames.pack_array(ids),
                "checksum": flt.payload_checksum(dists, ids),
                "k": k, "n_probe": n_probe}

    # -- one connection ------------------------------------------------------

    def _serve_conn(self, sock: socket.socket) -> None:
        codec = self.codec
        sock.sendall(frames.encode_frame(
            {"kind": frames.HELLO, "role": "worker", "wid": self.wid},
            codec))
        sock.sendall(frames.encode_frame(
            {"kind": frames.READY, "wid": self.wid, "svc": self.svc},
            codec))
        reader = frames.FrameReader()
        sock.settimeout(self.hb_interval / 2)
        next_hb = time.monotonic() + self.hb_interval
        while not self.stop:
            now = time.monotonic()
            if now >= next_hb:
                sock.sendall(frames.encode_frame(
                    {"kind": frames.HB, "wid": self.wid}, codec))
                next_hb = now + self.hb_interval
            try:
                data = sock.recv(65536)
            except socket.timeout:
                continue
            if not data:
                return                  # master closed: dial again
            for frame in reader.feed(data):
                kind = frame.get("kind")
                if kind == frames.REQ:
                    sock.sendall(frames.encode_frame(
                        self._handle_req(frame), codec))
                elif kind == frames.BYE:
                    self.stop = True
                    return
                # anything else from the master is ignorable chatter

    # -- the dial loop -------------------------------------------------------

    def run(self) -> int:
        dials = 0
        backoff = self.reconnect_base
        while not self.stop:
            dials += 1
            if self.max_dials and dials > self.max_dials:
                return 1
            try:
                sock = connect_addr(self.addr)
            except OSError:
                time.sleep(backoff)
                backoff = min(backoff * 2, self.reconnect_cap)
                continue
            backoff = self.reconnect_base
            try:
                self._serve_conn(sock)
            except (frames.FrameError, OSError):
                pass                    # corrupt stream / broken pipe: redial
            finally:
                try:
                    if self.stop:
                        sock.sendall(frames.encode_frame(
                            {"kind": frames.BYE, "wid": self.wid},
                            self.codec))
                except OSError:
                    pass
                sock.close()
        return 0


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print("usage: python -m repro.transport.worker <spec.json>",
              file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        spec = json.load(f)
    app = WorkerApp(spec)

    def _term(signum, _frame):
        app.stop = True
    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    if os.environ.get("REPRO_WORKER_EXIT_AFTER"):
        # test hook: die after N served requests (exercises the master's
        # death-detection + respawn path without raw SIGKILL races)
        limit = int(os.environ["REPRO_WORKER_EXIT_AFTER"])
        orig = app._handle_req

        def wrapped(frame):
            out = orig(frame)
            if app.served >= limit:
                os._exit(17)
            return out
        app._handle_req = wrapped
    return app.run()


if __name__ == "__main__":
    sys.exit(main(sys.argv))
