"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is scatter/gather based (not the GShard one-hot einsum, whose
(tokens x experts x capacity) dispatch tensor and FLOPs dwarf the expert
compute at large batch): each (token, choice) computes its position inside
its expert's capacity buffer from a cumulative count, then a scatter builds
the (E, C, d) expert batch and a gather combines the outputs.  Compiled FLOPs
therefore reflect only the active-expert compute (6 * N_active * D), keeping
the roofline MODEL_FLOPS ratio honest for the MoE architectures.

Experts are stacked (E, d, ff) and shard over the 'model' mesh axis (EP);
the scatter/gather indices are data-local, so cross-shard traffic is the
expert-weight all-gather / activation all-to-all the partitioner inserts on
the batched matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding as shard

Params = dict


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    s1 = float(d_model) ** -0.5
    s2 = float(d_ff) ** -0.5
    return {
        "router": jax.random.normal(ks[0], (d_model, n_experts), dtype) * s1,
        "w_gate": jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype) * s1,
        "w_up": jax.random.normal(ks[2], (n_experts, d_model, d_ff), dtype) * s1,
        "w_down": jax.random.normal(ks[3], (n_experts, d_ff, d_model), dtype) * s2,
    }


def moe_forward(p: Params, x: jax.Array, top_k: int,
                capacity_factor: float = 1.25) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Top-k routing, capacity bounded PER ROW.

    Grouping by batch row keeps dispatch local to the data shard (no global
    cumsum across chips); experts see a (B, E, C, d) batch, C = S*k/E*cf."""
    b, s, d = x.shape
    e = p["router"].shape[1]

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, top_k)            # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    capacity = int(max(s * top_k / e * capacity_factor, 4))
    capacity = min(capacity, s)

    # Rank of each (token, choice) within its expert, per row.  Sort-based:
    # O(T log T) work, O(T) memory — the cumsum-of-one-hot alternative
    # materializes a (B, S*k, E) tensor that dwarfs everything else at
    # dbrx-scale batch*seq.
    flat_sel = sel.reshape(b, s * top_k)                    # (B, S*k)
    t = s * top_k

    def rank_row(sel_r):
        order = jnp.argsort(sel_r, stable=True)
        sorted_sel = sel_r[order]
        # index of the first occurrence of each expert id in the sorted row
        first = jnp.searchsorted(sorted_sel, sorted_sel, side="left")
        rank_sorted = jnp.arange(t, dtype=jnp.int32) - first.astype(jnp.int32)
        return jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)

    pos = jax.vmap(rank_row)(flat_sel)
    keep = pos < capacity
    slot = jnp.where(keep, flat_sel * capacity + pos, e * capacity)

    def slot_maps(slot_r, gate_r):
        # int32/fp32 (E*C,) maps: which token fills each slot + its gate.
        rows = jnp.repeat(jnp.arange(s, dtype=jnp.int32), top_k)
        tok_for_slot = jnp.full((e * capacity + 1,), s, jnp.int32)
        tok_for_slot = tok_for_slot.at[slot_r].set(rows, mode="drop")
        g_slot = jnp.zeros((e * capacity + 1,), jnp.float32)
        g_slot = g_slot.at[slot_r].set(gate_r.reshape(-1), mode="drop")
        return tok_for_slot[: e * capacity], g_slot[: e * capacity]

    tok_for_slot, gate_for_slot = jax.vmap(slot_maps)(slot, gate_vals)

    def dispatch_row(xr, tok_slot):
        # (S, d) -> (E*C, d): the d-wide data movement is a GATHER driven by
        # the tiny int32 slot-inverse map.
        xr_pad = jnp.concatenate([xr, jnp.zeros((1, d), x.dtype)])
        return xr_pad[tok_slot]

    xe = jax.vmap(dispatch_row)(x, tok_for_slot).reshape(b, e, capacity, d)

    # Expert FFN (SwiGLU), batched over (B, E); expert-parallel over 'model'.
    xe = shard.constrain(xe, ("pod", "data"), "model", None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = shard.constrain(h, ("pod", "data"), "model", None, None)
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])        # (B, E, C, d)
    ye = shard.constrain(ye, ("pod", "data"), "model", None, None)

    def combine_row(ye_r, tok_slot, g_slot):
        # Scatter-add from the expert layout back to tokens: the per-k gate
        # weighting and the sum over choices happen BEFORE the cross-shard
        # collective, so the E-sharded contribution reduce is (S, d) in bf16
        # instead of a (S*k, d) fp32 gather all-reduce (§Perf cell B, it2).
        yw = ye_r.reshape(e * capacity, d) * g_slot[:, None].astype(x.dtype)
        y = jnp.zeros((s + 1, d), x.dtype)
        return y.at[tok_slot].add(yw, mode="drop")[:s]

    y = jax.vmap(combine_row)(ye, tok_for_slot, gate_for_slot)
    return y.reshape(b, s, d)
