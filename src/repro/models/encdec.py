"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment the modality frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings (B, n_frames, d) instead of the mel+conv stack.
Encoder: bidirectional attention + GELU MLP, sinusoidal positions, LayerNorm.
Decoder: causal self-attention + cross-attention + GELU MLP, learned
positions.  Layer-stacked with lax.scan like the decoder-only stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import sharding as shard
from repro.models.transformer import LMConfig

Params = dict


def _sinusoid(n: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1).astype(dtype)


def init_encdec(key, cfg: LMConfig) -> Params:
    dt = cfg.dtype
    ks = jax.random.split(key, 6)
    dims = cfg.attn_dims()

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt), "lb1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt), "lb2": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attn(k1, dims, dt),
            "mlp": L.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt), "lb1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt), "lb2": jnp.zeros((cfg.d_model,), dt),
            "ln3": jnp.ones((cfg.d_model,), dt), "lb3": jnp.zeros((cfg.d_model,), dt),
            "self_attn": L.init_attn(k1, dims, dt),
            "cross_attn": L.init_attn(k2, dims, dt),
            "mlp": L.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, dt),
        }

    dec_n = cfg.dec_layers or cfg.n_layers
    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), dt) * 0.02,
        "pos_dec": jax.random.normal(ks[1], (40960, cfg.d_model), dt) * 0.01,
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[2], cfg.n_layers)),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[3], dec_n)),
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "enc_norm_b": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "final_norm_b": jnp.zeros((cfg.d_model,), dt),
        "unembed": jax.random.normal(ks[4], (cfg.d_model, cfg.vocab), dt)
        * (float(cfg.d_model) ** -0.5),
    }


def encode(params: Params, cfg: LMConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, n_frames, d) precomputed embeddings (frontend stub)."""
    b, s, d = frames.shape
    x = frames + _sinusoid(s, d, frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h, lp):
        z = L.layer_norm(h, lp["ln1"], lp["lb1"])
        h = h + L.attn_forward(lp["attn"], z, cfg.attn_dims(), positions,
                               causal=False, use_rope=False)
        z = L.layer_norm(h, lp["ln2"], lp["lb2"])
        h = h + L.gelu_mlp(lp["mlp"], z)
        return shard.constrain(h, ("pod", "data"), "model", None), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_norm"], params["enc_norm_b"])


def decode_train(params: Params, cfg: LMConfig, enc_out: jax.Array,
                 tokens: jax.Array) -> jax.Array:
    """Teacher-forced decoder: tokens (B, S_dec) -> logits."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_dec"][:s][None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_positions = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32), (b, enc_out.shape[1]))

    def body(h, lp):
        z = L.layer_norm(h, lp["ln1"], lp["lb1"])
        h = h + L.attn_forward(lp["self_attn"], z, cfg.attn_dims(), positions,
                               causal=True, use_rope=False)
        z = L.layer_norm(h, lp["ln2"], lp["lb2"])
        h = h + _cross_attn(lp["cross_attn"], z, enc_out, cfg)
        z = L.layer_norm(h, lp["ln3"], lp["lb3"])
        return h + L.gelu_mlp(lp["mlp"], z), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layer_norm(x, params["final_norm"], params["final_norm_b"])
    return x @ params["unembed"]


def _cross_attn(p: Params, x: jax.Array, enc_out: jax.Array, cfg: LMConfig):
    b, s, _ = x.shape
    dims = cfg.attn_dims()
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (enc_out @ p["wk"]).reshape(b, -1, kv, hd)
    v = (enc_out @ p["wv"]).reshape(b, -1, kv, hd)
    o = L.attention_scores(q, L.repeat_kv(k, h // kv), L.repeat_kv(v, h // kv),
                           causal=False)
    return o.reshape(b, s, h * hd) @ p["wo"]


def _dec_hidden(params: Params, cfg: LMConfig, enc_out: jax.Array,
                tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_dec"][:s][None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h, lp):
        z = L.layer_norm(h, lp["ln1"], lp["lb1"])
        h = h + L.attn_forward(lp["self_attn"], z, cfg.attn_dims(), positions,
                               causal=True, use_rope=False)
        z = L.layer_norm(h, lp["ln2"], lp["lb2"])
        h = h + _cross_attn(lp["cross_attn"], z, enc_out, cfg)
        z = L.layer_norm(h, lp["ln3"], lp["lb3"])
        h = h + L.gelu_mlp(lp["mlp"], z)
        return shard.constrain(h, ("pod", "data"), "model", None), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return L.layer_norm(x, params["final_norm"], params["final_norm_b"])


def prefill_last_logits(params: Params, cfg: LMConfig, frames: jax.Array,
                        tokens: jax.Array) -> jax.Array:
    enc = encode(params, cfg, frames)
    x = _dec_hidden(params, cfg, enc, tokens)
    return x[:, -1, :] @ params["unembed"]


LOSS_CHUNK = 1024


def loss(params: Params, cfg: LMConfig, frames: jax.Array, tokens: jax.Array,
         targets: jax.Array) -> jax.Array:
    enc = encode(params, cfg, frames)
    x = _dec_hidden(params, cfg, enc, tokens)
    b, s, d = x.shape
    chunk = min(LOSS_CHUNK, s)
    if s % chunk:
        chunk = s
    nc = s // chunk

    def body(tot, xs):
        xc, tc = xs
        logits = (xc @ params["unembed"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(ll), None

    xcs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tcs = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    # Remat per chunk: (B, chunk, V) logits are recomputed in the backward.
    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                          (xcs, tcs))
    return -tot / (b * s)


def init_decode_caches(cfg: LMConfig, batch: int, max_seq: int,
                       enc_out: jax.Array | None = None):
    dt = cfg.dtype
    dims = cfg.attn_dims()
    dec_n = cfg.dec_layers or cfg.n_layers
    caches = {
        "k": jnp.zeros((dec_n, batch, max_seq, dims.n_kv, dims.head_dim), dt),
        "v": jnp.zeros((dec_n, batch, max_seq, dims.n_kv, dims.head_dim), dt),
    }
    return caches


def decode_step(params: Params, cfg: LMConfig, token: jax.Array,
                caches: Params, pos: jax.Array, enc_out: jax.Array):
    """One decoder step with cross-attention over the (precomputed) encoder
    output."""
    b = token.shape[0]
    x = params["embed"][token][:, None, :]
    x = x + params["pos_dec"][pos][:, None, :]

    def body(h, xs):
        lp, ck, cv = xs
        z = L.layer_norm(h, lp["ln1"], lp["lb1"])
        att, (nk, nv) = _self_attn_decode(lp["self_attn"], z, cfg, ck, cv, pos)
        h = h + att
        z = L.layer_norm(h, lp["ln2"], lp["lb2"])
        h = h + _cross_attn(lp["cross_attn"], z, enc_out, cfg)
        z = L.layer_norm(h, lp["ln3"], lp["lb3"])
        return h + L.gelu_mlp(lp["mlp"], z), (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], caches["k"], caches["v"]))
    x = L.layer_norm(x, params["final_norm"], params["final_norm_b"])
    return (x @ params["unembed"])[:, 0, :], {"k": nk, "v": nv}


def _self_attn_decode(p, x, cfg, ck, cv, pos):
    dims = cfg.attn_dims()
    b = x.shape[0]
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, kv, hd)
    v = (x @ p["wv"]).reshape(b, 1, kv, hd)
    b_idx = jnp.arange(b, dtype=jnp.int32)
    ck = ck.at[b_idx, pos].set(k[:, 0])
    cv = cv.at[b_idx, pos].set(v[:, 0])
    kv_valid = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
    o = L.attention_scores(q, L.repeat_kv(ck, h // kv), L.repeat_kv(cv, h // kv),
                           causal=False, kv_valid=kv_valid)
    return o.reshape(b, 1, h * hd) @ p["wo"], (ck, cv)
