"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan +
O(1)-per-token recurrent decode.

Shapes follow the Mamba2 paper: d_inner = expand * d_model, H = d_inner /
headdim heads, shared (ngroups=1) B/C of size N = d_state, scalar-per-head A,
softplus dt with bias, width-4 causal depthwise conv on (x, B, C), gated
RMSNorm output.

Train/prefill use the SSD block decomposition with chunk length L: the
intra-chunk term is an (L x L) masked "attention" per head (materialized per
scan step only — live memory O(B*H*L^2)), the inter-chunk term propagates the
(B, H, P, N) state through a lax.scan.  Decode is the recurrence
    h <- h * exp(dt*A) + dt * (x ⊗ B);   y = C·h + D*x
which is what makes the ``long_500k`` decode shape feasible (state is O(1) in
sequence length).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict


@dataclasses.dataclass(frozen=True)
class SSMDims:
    """State-space (Mamba-style) block dimensions."""
    d_model: int
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def d_conv_ch(self) -> int:
        return self.d_inner + 2 * self.d_state

    @property
    def d_in_proj(self) -> int:
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads


def init_ssm(key, dims: SSMDims, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    s = float(dims.d_model) ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (dims.d_model, dims.d_in_proj), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (dims.conv_width, dims.d_conv_ch), dtype) * 0.2,
        "conv_b": jnp.zeros((dims.d_conv_ch,), dtype),
        "a_log": jnp.zeros((dims.n_heads,), jnp.float32),          # A = -exp(0) = -1
        "d_skip": jnp.ones((dims.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((dims.n_heads,), jnp.float32),
        "norm_scale": jnp.ones((dims.d_inner,), dtype),
        "out_proj": jax.random.normal(
            ks[2], (dims.d_inner, dims.d_model), dtype) * (float(dims.d_inner) ** -0.5),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 cache: jax.Array | None = None):
    """Depthwise causal conv over S.  xbc: (B, S, C), w: (W, C).
    Returns (out (B,S,C), new_cache (B, W-1, C))."""
    width = w.shape[0]
    if cache is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, xbc], axis=1)      # (B, S+W-1, C)
    out = sum(xp[:, i: i + xbc.shape[1], :] * w[i] for i in range(width))
    new_cache = xp[:, -(width - 1):, :]
    return jax.nn.silu(out + b), new_cache


def _split_proj(p: Params, x: jax.Array, dims: SSMDims):
    zxbcdt = x @ p["in_proj"]
    di, n, h = dims.d_inner, dims.d_state, dims.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xbc, dt


def ssd_chunked(
    xh: jax.Array,    # (B, S, H, P)
    bm: jax.Array,    # (B, S, N)
    cm: jax.Array,    # (B, S, N)
    dt: jax.Array,    # (B, S, H) fp32
    a: jax.Array,     # (H,) fp32 (negative)
    h0: jax.Array | None = None,   # (B, H, P, N)
    chunk: int = 128,
):
    """SSD dual-form scan.  Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = xh.reshape(b, nc, chunk, h, p)
    bc = bm.reshape(b, nc, chunk, n)
    cc = cm.reshape(b, nc, chunk, n)
    dtc = dt.reshape(b, nc, chunk, h)
    da = dtc * a                                   # (B, nc, L, H), <= 0

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hstate, xs):
        xcs, bcs, ccs, dacs, dtcs = xs             # per-chunk (B, L, ...)
        lcs = jnp.cumsum(dacs, axis=1)             # (B, L, H)
        # --- intra-chunk (masked attention form) ---
        cb = jnp.einsum("bin,bjn->bij", ccs.astype(jnp.float32),
                        bcs.astype(jnp.float32))   # (B, L, L)
        dmat = lcs[:, :, None, :] - lcs[:, None, :, :]        # (B, L, L, H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        mat = jnp.where(causal[None, :, :, None],
                        jnp.exp(dmat) * dtcs[:, None, :, :], 0.0)
        mat = mat * cb[..., None]                  # (B, L, L, H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", mat, xcs.astype(jnp.float32))
        # --- inter-chunk (carry state in) ---
        y_inter = jnp.einsum("bin,bhpn->bihp", ccs.astype(jnp.float32), hstate)
        y_inter = y_inter * jnp.exp(lcs)[:, :, :, None]     # decay since entry
        # --- state update ---
        total = lcs[:, -1, :]                      # (B, H)
        decay_to_end = jnp.exp(total[:, None, :] - lcs)       # (B, L, H)
        contrib = jnp.einsum(
            "bjhp,bjn->bhpn",
            xcs.astype(jnp.float32) * (dtcs * decay_to_end)[..., None],
            bcs.astype(jnp.float32))
        hnew = hstate * jnp.exp(total)[:, :, None, None] + contrib
        return hnew, (y_intra + y_inter)

    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        bc.transpose(1, 0, 2, 3),
        cc.transpose(1, 0, 2, 3),
        da.transpose(1, 0, 2, 3),
        dtc.transpose(1, 0, 2, 3),
    )
    # Remat per chunk: the (B, L, L, H) intra-chunk tensors are recomputed
    # in the backward instead of being saved for every chunk.
    h_final, ys = jax.lax.scan(jax.checkpoint(step), h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, h_final


def ssm_forward(p: Params, x: jax.Array, dims: SSMDims, chunk: int = 128,
                h0=None, conv_cache=None, return_state: bool = False):
    """Full Mamba2 block, train/prefill mode.  x: (B, S, d_model)."""
    b, s, _ = x.shape
    di, n, h, pd = dims.d_inner, dims.d_state, dims.n_heads, dims.headdim
    z, xbc, dt = _split_proj(p, x, dims)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xi = xbc[..., :di].reshape(b, s, h, pd)
    bm = xbc[..., di: di + n]
    cm = xbc[..., di + n:]
    a = -jnp.exp(p["a_log"])
    y, h_final = ssd_chunked(xi, bm, cm, dt, a, h0=h0, chunk=min(chunk, s))
    y = y + p["d_skip"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ p["out_proj"]
    if return_state:
        return out, (h_final, new_conv)
    return out


def ssm_decode(p: Params, x: jax.Array, dims: SSMDims,
               h: jax.Array, conv_cache: jax.Array):
    """One-token decode.  x: (B, 1, d_model); h: (B, H, P, N);
    conv_cache: (B, W-1, C)."""
    b = x.shape[0]
    di, n, hh, pd = dims.d_inner, dims.d_state, dims.n_heads, dims.headdim
    z, xbc, dt = _split_proj(p, x, dims)          # (B, 1, ...)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xi = xbc[:, 0, :di].reshape(b, hh, pd)
    bm = xbc[:, 0, di: di + n]
    cm = xbc[:, 0, di + n:]
    dt0 = dt[:, 0]                                 # (B, H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt0 * a)                       # (B, H)
    contrib = jnp.einsum("bhp,bn->bhpn", xi.astype(jnp.float32) * dt0[..., None],
                         bm.astype(jnp.float32))
    h = h * decay[:, :, None, None] + contrib
    y = jnp.einsum("bhpn,bn->bhp", h, cm.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], (h, new_conv)
