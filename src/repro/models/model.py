"""Unified model API: build(config) -> init / train_step / serve steps.

``train_step`` is the object the dry-run lowers for ``train_4k``;
``decode_step`` (token + caches) for ``decode_32k`` / ``long_500k``;
``forward`` for ``prefill_32k`` (prefill compute == forward; cache export is
a layout copy the serving runtime owns — recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as tf
from repro.models.transformer import LMConfig
from repro.optim import adamw


class Model(NamedTuple):
    """Bundled model callables: init, loss, forward, prefill, decode."""
    cfg: LMConfig
    init: Any
    loss_fn: Any
    forward: Any
    prefill: Any            # full-seq backbone, last-token logits
    decode_step: Any
    init_caches: Any


def build(cfg: LMConfig) -> Model:
    if cfg.family == "encdec":
        def init(key):
            return encdec_mod.init_encdec(key, cfg)

        def loss_fn(params, batch):
            return encdec_mod.loss(params, cfg, batch["frames"],
                                   batch["tokens"], batch["targets"])

        def forward(params, batch):
            enc = encdec_mod.encode(params, cfg, batch["frames"])
            return encdec_mod.decode_train(params, cfg, enc, batch["tokens"])

        def decode_step(params, batch, caches):
            return encdec_mod.decode_step(
                params, cfg, batch["token"], caches, batch["pos"],
                batch["enc_out"])

        def prefill(params, batch):
            return encdec_mod.prefill_last_logits(
                params, cfg, batch["frames"], batch["tokens"])

        def init_caches(batch, max_seq):
            return encdec_mod.init_decode_caches(cfg, batch, max_seq)

        return Model(cfg, init, loss_fn, forward, prefill, decode_step,
                     init_caches)

    def init(key):
        return tf.init_lm(key, cfg)

    def loss_fn(params, batch):
        return tf.lm_loss(params, cfg, batch["tokens"], batch["targets"],
                          batch.get("patch_embeds"))

    def forward(params, batch):
        return tf.forward(params, cfg, batch["tokens"],
                          batch.get("patch_embeds"))

    def decode_step(params, batch, caches):
        return tf.decode_step(params, cfg, batch["token"], caches,
                              batch["pos"])

    def prefill(params, batch):
        return tf.prefill_last_logits(params, cfg, batch["tokens"],
                                      batch.get("patch_embeds"))

    def init_caches(batch, max_seq):
        return tf.init_decode_caches(cfg, batch, max_seq)

    return Model(cfg, init, loss_fn, forward, prefill, decode_step,
                 init_caches)


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    n_microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``n_microbatches > 1`` enables gradient accumulation: the global batch is
    split along its leading axis and scanned, so per-microbatch activation
    transients (flash blocks, MoE expert buffers, saved carries) shrink by
    the microbatch factor while the optimizer semantics are unchanged.
    """
    if n_microbatches == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            params, opt_state, metrics = adamw.update(
                grads, opt_state, params, opt_cfg)
            metrics["loss"] = loss
            return params, opt_state, metrics

        return train_step

    from repro.models import sharding as shard

    def split(x):
        mb = n_microbatches
        y = x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
        # keep the microbatch shards on the batch axes after the reshape
        return shard.constrain(
            y, None, ("pod", "data"), *([None] * (y.ndim - 2)))

    def train_step(params, opt_state, batch):
        mbs = jax.tree.map(split, batch)

        def body(acc, mb):
            g_acc, l_acc = acc
            loss, grads = jax.value_and_grad(model.loss_fn)(params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, grads)
            return (g_acc, l_acc + loss), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (g_sum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / n_microbatches, g_sum)
        params, opt_state, metrics = adamw.update(grads, opt_state, params,
                                                  opt_cfg)
        metrics["loss"] = loss_sum / n_microbatches
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model):
    """(params, batch, caches) -> (logits, new_caches) — one decode token."""

    def serve_step(params, batch, caches):
        return model.decode_step(params, batch, caches)

    return serve_step


def param_count(params) -> int:
    return sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
