"""Decoder-only LM stack covering dense / MoE / SSM / hybrid families.

All layer weights are stacked with a leading (L, ...) axis and consumed by
``lax.scan`` — the HLO contains ONE layer body regardless of depth, which is
what keeps the 512-device SPMD dry-run compiles tractable.  Hybrid models
(Zamba2) scan over segments: ``ssm_per_segment`` stacked Mamba2 layers plus a
single SHARED attention block applied once per segment (weight re-use, as in
the Zamba2 paper).

Modes:
  forward(tokens | embeds)     -> logits            (train / prefill compute)
  prefill(tokens)              -> logits, caches    (builds decode state)
  decode(token, caches, pos)   -> logits, caches    (one step)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import sharding as shard
from repro.models import ssm as ssm_mod

Params = dict


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Language-model architecture configuration."""
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    d_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    # hybrid (Zamba2-style shared attention)
    ssm_per_segment: int = 0    # >0 => hybrid: scan segments of ssm + shared attn
    # frontends (vlm / audio stubs)
    n_patches: int = 0          # vlm: prepended image patch embeddings
    n_frames: int = 0           # audio: encoder frame count (encdec only)
    dec_layers: int = 0         # encdec: decoder depth (n_layers = encoder)
    dtype: Any = jnp.float32
    remat: bool = False         # activation checkpointing per layer
    kv_quant: bool = False      # int8 KV cache (decode path), per-position scale

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_dims(self) -> L.AttnDims:
        return L.AttnDims(self.d_model, self.n_heads, self.n_kv, self.hd,
                          self.qkv_bias, self.rope_theta)

    def ssm_dims(self) -> ssm_mod.SSMDims:
        return ssm_mod.SSMDims(self.d_model, self.d_state, self.ssm_expand,
                               self.ssm_headdim)

    @property
    def n_segments(self) -> int:
        assert self.ssm_per_segment > 0
        return self.n_layers // self.ssm_per_segment


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_lm(key, cfg: LMConfig) -> Params:
    ks = jax.random.split(key, 8)
    dt = cfg.dtype
    p: Params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), dt) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "unembed": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), dt)
        * (float(cfg.d_model) ** -0.5),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        def one_layer(k):
            k1, k2 = jax.random.split(k)
            lp = {
                "ln1": jnp.ones((cfg.d_model,), dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "attn": L.init_attn(k1, cfg.attn_dims(), dt),
            }
            if cfg.family == "moe":
                lp["moe"] = moe_mod.init_moe(k2, cfg.d_model, cfg.d_ff,
                                             cfg.n_experts, dt)
            else:
                lp["mlp"] = L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dt)
            return lp

        p["layers"] = _stack_init(ks[2], cfg.n_layers, one_layer)
    elif cfg.family == "ssm":
        def one_layer(k):
            return {
                "ln1": jnp.ones((cfg.d_model,), dt),
                "ssm": ssm_mod.init_ssm(k, cfg.ssm_dims(), dt),
            }
        p["layers"] = _stack_init(ks[2], cfg.n_layers, one_layer)
    elif cfg.family == "hybrid":
        def one_ssm(k):
            return {
                "ln1": jnp.ones((cfg.d_model,), dt),
                "ssm": ssm_mod.init_ssm(k, cfg.ssm_dims(), dt),
            }
        nseg, per = cfg.n_segments, cfg.ssm_per_segment
        p["layers"] = jax.vmap(
            lambda k: _stack_init(k, per, one_ssm)
        )(jax.random.split(ks[2], nseg))            # (nseg, per, ...)
        k1, k2 = jax.random.split(ks[3])
        p["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attn(k1, cfg.attn_dims(), dt),
            "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dt),
        }
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        # frontend stub: projection applied to precomputed patch embeddings
        p["patch_proj"] = jax.random.normal(
            ks[4], (cfg.d_model, cfg.d_model), dt) * 0.02
    return p


# --------------------------------------------------------------------------
# layer bodies
# --------------------------------------------------------------------------

def _sp_out(y):
    """Constrain a block-branch output to the sequence-parallel layout so the
    TP partial-sum lands as a reduce-scatter, not all-reduce+slice
    (EXPERIMENTS.md §Perf cell B)."""
    return shard.constrain(y, ("pod", "data"), "model", None)


def _attn_block(lp, x, cfg: LMConfig, positions, causal=True):
    att = L.attn_forward(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                         cfg.attn_dims(), positions, causal=causal)
    h = x + _sp_out(att)
    z = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        return h + _sp_out(moe_mod.moe_forward(lp["moe"], z, cfg.top_k,
                                               cfg.capacity_factor))
    return h + _sp_out(L.swiglu(lp["mlp"], z))


def _ssm_block(lp, x, cfg: LMConfig):
    return x + ssm_mod.ssm_forward(
        lp["ssm"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg.ssm_dims(),
        chunk=cfg.ssm_chunk)


def _maybe_remat(fn, cfg: LMConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


# --------------------------------------------------------------------------
# forward (train / prefill compute)
# --------------------------------------------------------------------------

def forward(params: Params, cfg: LMConfig, tokens: jax.Array,
            patch_embeds: jax.Array | None = None) -> jax.Array:
    """tokens (B, S) -> logits (B, S, vocab).  For vlm, ``patch_embeds``
    (B, n_patches, d) are projected and prepended (their logits are produced
    too; the loss masks them)."""
    return _hidden(params, cfg, tokens, patch_embeds) @ params["unembed"]


def _hidden(params: Params, cfg: LMConfig, tokens: jax.Array,
            patch_embeds: jax.Array | None = None) -> jax.Array:
    """Backbone without the unembed projection (shared by loss / prefill)."""
    x = params["embed"][tokens]
    if cfg.family == "vlm":
        assert patch_embeds is not None
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def _sp(h):
        # Sequence-parallel carry sharding (Megatron SP analogue): the layer
        # scan saves its carry per layer for the backward; sharding the
        # sequence axis over "model" cuts that saved-activation footprint by
        # |model| (XLA inserts the all-gather at layer entry / reduce-scatter
        # at exit).  No-op when S is indivisible or no mesh is ambient.
        return shard.constrain(h, ("pod", "data"), "model", None)

    x = _sp(x)
    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, lp):
            return _sp(_maybe_remat(
                lambda hh: _attn_block(lp, hh, cfg, positions), cfg)(h)), None
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "ssm":
        def body(h, lp):
            return _sp(_maybe_remat(
                lambda hh: _ssm_block(lp, hh, cfg), cfg)(h)), None
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def seg_body(h, seg_lp):
            def inner(hh, lp):
                return _sp(_ssm_block(lp, hh, cfg)), None
            h, _ = jax.lax.scan(inner, h, seg_lp)
            h = _sp(_maybe_remat(
                lambda hh: _attn_block(shared, hh, cfg, positions), cfg)(h))
            return h, None

        x, _ = jax.lax.scan(seg_body, x, params["layers"])
    else:
        raise ValueError(cfg.family)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def prefill_last_logits(params: Params, cfg: LMConfig, tokens: jax.Array,
                        patch_embeds: jax.Array | None = None) -> jax.Array:
    """Inference-prefill step: full-sequence backbone compute, logits for the
    LAST position only (the serving runtime owns the KV-cache export; the
    dominant cost — the backbone — is what this lowers)."""
    x = _hidden(params, cfg, tokens, patch_embeds)
    return x[:, -1, :] @ params["unembed"]


LOSS_CHUNK = 1024  # sequence chunk for the cross-entropy (bounds (B,c,V) temp)


def lm_loss(params: Params, cfg: LMConfig, tokens: jax.Array,
            targets: jax.Array, patch_embeds: jax.Array | None = None) -> jax.Array:
    x = _hidden(params, cfg, tokens, patch_embeds)
    if cfg.family == "vlm":
        x = x[:, cfg.n_patches:, :]
    b, s, d = x.shape
    chunk = min(LOSS_CHUNK, s)
    if s % chunk:
        chunk = s
    nc = s // chunk

    def body(tot, xs):
        xc, tc = xs                                  # (B, c, d), (B, c)
        logits = (xc @ params["unembed"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(ll), None

    xcs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tcs = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    # Remat per chunk: (B, chunk, V) logits are recomputed in the backward.
    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                          (xcs, tcs))
    return -tot / (b * s)


# --------------------------------------------------------------------------
# decode path (serve_step)
# --------------------------------------------------------------------------

def init_decode_caches(cfg: LMConfig, batch: int, max_seq: int) -> Params:
    """Static-shape decode state: KV caches for attention layers, (h, conv)
    state for SSM layers."""
    dt = cfg.dtype
    hd, kv = cfg.hd, cfg.n_kv
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.kv_quant:
            return {
                "k": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), jnp.int8),
                "v": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), jnp.int8),
                "k_scale": jnp.zeros((cfg.n_layers, batch, max_seq), jnp.float32),
                "v_scale": jnp.zeros((cfg.n_layers, batch, max_seq), jnp.float32),
            }
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dt),
        }
    sd = cfg.ssm_dims()
    if cfg.family == "ssm":
        return {
            "h": jnp.zeros((cfg.n_layers, batch, sd.n_heads, sd.headdim,
                            sd.d_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, sd.conv_width - 1,
                               sd.d_conv_ch), dt),
        }
    if cfg.family == "hybrid":
        nseg, per = cfg.n_segments, cfg.ssm_per_segment
        return {
            "h": jnp.zeros((nseg, per, batch, sd.n_heads, sd.headdim,
                            sd.d_state), jnp.float32),
            "conv": jnp.zeros((nseg, per, batch, sd.conv_width - 1,
                               sd.d_conv_ch), dt),
            # shared attention block: one cache per segment invocation
            "k": jnp.zeros((nseg, batch, max_seq, kv, hd), dt),
            "v": jnp.zeros((nseg, batch, max_seq, kv, hd), dt),
        }
    raise ValueError(cfg.family)


def decode_step(params: Params, cfg: LMConfig, token: jax.Array,
                caches: Params, pos: jax.Array):
    """token (B,) -> (logits (B, vocab), new caches).  pos (B,) is the index
    the new token occupies (caches valid strictly before it)."""
    x = params["embed"][token][:, None, :]           # (B, 1, d)
    b = x.shape[0]

    if cfg.family in ("dense", "moe", "vlm"):
        # KV caches ride the scan CARRY with dynamic-index updates so XLA can
        # alias the (donated) cache buffers in place; passing them as scan
        # xs/ys materializes a full-cache copy for the stacked outputs.
        quant = cfg.kv_quant

        def body(carry, lp):
            if quant:
                h, ck_all, cv_all, ks_all, vs_all, i = carry
                ck_q = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
                cv_q = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
                ks = jax.lax.dynamic_index_in_dim(ks_all, i, 0, keepdims=False)
                vs = jax.lax.dynamic_index_in_dim(vs_all, i, 0, keepdims=False)
                # dequantize per position (on TPU a fused kernel dequantizes
                # in registers; the dry-run lowers the jnp form)
                ck = (ck_q.astype(cfg.dtype)
                      * ks[..., None, None].astype(cfg.dtype))
                cv = (cv_q.astype(cfg.dtype)
                      * vs[..., None, None].astype(cfg.dtype))
            else:
                h, ck_all, cv_all, i = carry
                ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            z = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            att, (nk, nv) = L.attn_decode(lp["attn"], z, cfg.attn_dims(),
                                          ck, cv, pos)
            h = h + att
            z2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                h = h + moe_mod.moe_forward(lp["moe"], z2, cfg.top_k,
                                            cfg.capacity_factor)
            else:
                h = h + L.swiglu(lp["mlp"], z2)
            if quant:
                # quantize ONLY the new position back into the int8 cache
                b_idx = jnp.arange(h.shape[0], dtype=jnp.int32)
                new_k = nk[b_idx, pos]                     # (B, kv, hd)
                new_v = nv[b_idx, pos]
                sk = jnp.max(jnp.abs(new_k.astype(jnp.float32)),
                             axis=(-2, -1)) / 127.0 + 1e-9
                sv = jnp.max(jnp.abs(new_v.astype(jnp.float32)),
                             axis=(-2, -1)) / 127.0 + 1e-9
                qk = jnp.clip(jnp.round(new_k.astype(jnp.float32)
                                        / sk[:, None, None]), -127, 127
                              ).astype(jnp.int8)
                qv = jnp.clip(jnp.round(new_v.astype(jnp.float32)
                                        / sv[:, None, None]), -127, 127
                              ).astype(jnp.int8)
                ck_q = ck_q.at[b_idx, pos].set(qk)
                cv_q = cv_q.at[b_idx, pos].set(qv)
                ks = ks.at[b_idx, pos].set(sk)
                vs = vs.at[b_idx, pos].set(sv)
                ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck_q, i, 0)
                cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv_q, i, 0)
                ks_all = jax.lax.dynamic_update_index_in_dim(ks_all, ks, i, 0)
                vs_all = jax.lax.dynamic_update_index_in_dim(vs_all, vs, i, 0)
                return (h, ck_all, cv_all, ks_all, vs_all, i + 1), None
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, nk, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, nv, i, 0)
            return (h, ck_all, cv_all, i + 1), None

        if quant:
            carry0 = (x, caches["k"], caches["v"], caches["k_scale"],
                      caches["v_scale"], jnp.int32(0))
            (x, nk, nv, nks, nvs, _), _ = jax.lax.scan(body, carry0,
                                                       params["layers"])
            new_caches = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
        else:
            carry0 = (x, caches["k"], caches["v"], jnp.int32(0))
            (x, nk, nv, _), _ = jax.lax.scan(body, carry0, params["layers"])
            new_caches = {"k": nk, "v": nv}
    elif cfg.family == "ssm":
        def body(h, lp_cache):
            lp, hs, conv = lp_cache
            z = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            y, (nh, nconv) = ssm_mod.ssm_decode(lp["ssm"], z, cfg.ssm_dims(),
                                                hs, conv)
            return h + y, (nh, nconv)

        x, (nh, nconv) = jax.lax.scan(
            body, x, (params["layers"], caches["h"], caches["conv"]))
        new_caches = {"h": nh, "conv": nconv}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def seg_body(carry, xs):
            h, ck_all, cv_all, i = carry
            seg_lp, hs, conv = xs

            def inner(hh, ys):
                lp, hs1, conv1 = ys
                z = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
                y, (nh1, nconv1) = ssm_mod.ssm_decode(
                    lp["ssm"], z, cfg.ssm_dims(), hs1, conv1)
                return hh + y, (nh1, nconv1)

            h, (nh, nconv) = jax.lax.scan(inner, h, (seg_lp, hs, conv))
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            z = L.rms_norm(h, shared["ln1"], cfg.norm_eps)
            att, (nk, nv) = L.attn_decode(shared["attn"], z, cfg.attn_dims(),
                                          ck, cv, pos)
            h = h + att
            z2 = L.rms_norm(h, shared["ln2"], cfg.norm_eps)
            h = h + L.swiglu(shared["mlp"], z2)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, nk, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, nv, i, 0)
            return (h, ck_all, cv_all, i + 1), (nh, nconv)

        carry0 = (x, caches["k"], caches["v"], jnp.int32(0))
        (x, nk, nv, _), (nh, nconv) = jax.lax.scan(
            seg_body, carry0,
            (params["layers"], caches["h"], caches["conv"]))
        new_caches = {"h": nh, "conv": nconv, "k": nk, "v": nv}
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"])[:, 0, :]
    return logits, new_caches
