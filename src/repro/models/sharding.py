"""Activation-sharding hints usable from inside model code.

``constrain(x, *axes)`` applies ``with_sharding_constraint`` only when a mesh
is ambient (jit under ``with mesh:``), the named axes exist on it, and every
constrained dimension is divisible by its axis size — so model code stays
mesh-agnostic and runs unchanged in single-device smoke tests.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        m = jax._src.mesh.thread_resources.env.physical_mesh  # noqa: SLF001
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.shape_tuple:
            return m
    except Exception:  # noqa: BLE001
        pass
    return None


def constrain(x: jax.Array, *axes):
    """axes: one entry per dim — an axis name, a tuple of names, or None."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if hasattr(
        mesh, "devices") else dict(mesh.shape_tuple)

    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        group = ax if isinstance(ax, tuple) else (ax,)
        # keep the axes that exist on this mesh (e.g. "pod" is absent on the
        # single-pod mesh — the rest of the group still applies)
        group = tuple(a for a in group if a in names)
        if not group:
            spec.append(None)
            continue
        total = 1
        for a in group:
            total *= sizes[a]
        if dim % total == 0 and dim >= total:
            spec.append(group if len(group) > 1 else group[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
