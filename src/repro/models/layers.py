"""Shared layer primitives: norms, RoPE, GQA attention, MLPs.

Conventions:
  * params are plain dicts of jnp arrays; layer-stacked weights carry a
    leading (L, ...) axis consumed by lax.scan (keeps HLO O(1 layer),
    essential for the 512-device SPMD compiles).
  * activations default to bf16-ready fp32 (dtype passed by config); all
    reductions in fp32.
  * attention supports three modes: full causal (train/prefill), cached
    decode (one token vs a seq_len cache), and bidirectional (encoders).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Params = dict


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)) * scale + bias).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(..., S, n_kv, hd) -> (..., S, n_kv * n_rep, hd) (GQA head sharing)."""
    if n_rep == 1:
        return x
    b = x.shape[:-2]
    s_kv, hd = x.shape[-2], x.shape[-1]
    x = jnp.broadcast_to(x[..., :, None, :], (*b, s_kv, n_rep, hd))
    return x.reshape(*b[:-1], b[-1], s_kv * n_rep, hd)


def attention_scores(
    q: jax.Array,            # (B, S_q, H, hd)
    k: jax.Array,            # (B, S_k, H, hd)
    v: jax.Array,            # (B, S_k, H, hd)
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_valid: jax.Array | None = None,   # (B, S_k) cache-validity mask
) -> jax.Array:
    """Plain softmax attention (fp32 softmax).  Returns (B, S_q, H, hd)."""
    hd = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    s_q, s_k = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(s_q)[:, None] + q_offset
        kpos = jnp.arange(s_k)[None, :]
        mask = kpos <= qpos                     # (S_q, S_k)
        logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_valid is not None:
        logits = jnp.where(kv_valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (trace-time)."""
    for c in range(min(target, s), 0, -1):
        if s % c == 0:
            return c
    return s


def flash_attention(
    q: jax.Array,            # (B, S_q, H, hd)
    k: jax.Array,            # (B, S_k, H, hd)
    v: jax.Array,
    causal: bool,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Memory-efficient attention: online-softmax over KV chunks, scanned over
    Q chunks.  Peak live tensor is (B, H, q_chunk, kv_chunk) instead of
    (B, H, S, S) — required for the 32k-sequence shapes.  Pure jnp (the TPU
    deployment can swap a Pallas flash kernel; the dry-run lowers this)."""
    b, s_q, h, hd = q.shape
    s_k = k.shape[1]
    q_chunk = _pick_chunk(s_q, q_chunk)
    kv_chunk = _pick_chunk(s_k, kv_chunk)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    nq, nk = s_q // q_chunk, s_k // kv_chunk

    qs = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,hd)
    ks = k.reshape(b, nk, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)

    def q_body(_, qi_idx):
        qi, iq = qi_idx
        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)

        def kv_body(carry, kj_idx):
            m, l, acc = carry
            kj, vj, jk = kj_idx
            s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            if causal:
                qpos = iq * q_chunk + jnp.arange(q_chunk)
                kpos = jk * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None],
                              s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        # Remat per KV block: real flash attention never stores the (qc, kc)
        # score/probability blocks — the backward recomputes them.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_body), (m0, l0, a0),
            (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    # (nq, B, H, qc, hd) -> (B, S_q, H, hd)
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, s_q, h, hd)


FLASH_THRESHOLD = 2048  # use chunked attention at/above this sequence length


@dataclasses.dataclass(frozen=True)
class AttnDims:
    """Attention dimensions (heads, kv heads, head width, rope base)."""
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0


def init_attn(key, dims: AttnDims, dtype=jnp.float32) -> Params:
    d, h, kv, hd = dims.d_model, dims.n_heads, dims.n_kv, dims.head_dim
    ks = jax.random.split(key, 4)
    scale = float(d) ** -0.5  # python float: weak type, preserves bf16
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * scale,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dtype) * scale,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dtype) * scale,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) * scale,
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def attn_forward(
    p: Params,
    x: jax.Array,                    # (B, S, d)
    dims: AttnDims,
    positions: jax.Array,            # (B, S)
    causal: bool = True,
    use_rope: bool = True,
) -> jax.Array:
    b, s, d = x.shape
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if use_rope:
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
    k = repeat_kv(k, h // kv)
    v = repeat_kv(v, h // kv)
    if s >= FLASH_THRESHOLD:
        o = flash_attention(q, k, v, causal=causal)
    else:
        o = attention_scores(q, k, v, causal=causal)
    return o.reshape(b, s, h * hd) @ p["wo"]


def attn_prefill(p: Params, x: jax.Array, dims: AttnDims, positions: jax.Array):
    """Like attn_forward but also returns the (k, v) cache (pre-repeat)."""
    b, s, d = x.shape
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    q = rope(q, positions, dims.rope_theta)
    k = rope(k, positions, dims.rope_theta)
    o = attention_scores(q, repeat_kv(k, h // kv), repeat_kv(v, h // kv),
                         causal=True)
    return o.reshape(b, s, h * hd) @ p["wo"], (k, v)


def attn_decode(
    p: Params,
    x: jax.Array,                    # (B, 1, d) new token
    dims: AttnDims,
    cache_k: jax.Array,              # (B, S_max, kv, hd)
    cache_v: jax.Array,
    pos: jax.Array,                  # (B,) current position
):
    """One-token decode against a static-size cache (in-place dynamic update)."""
    b, _, d = x.shape
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kv, hd)
    v = v.reshape(b, 1, kv, hd)
    q = rope(q, pos[:, None], dims.rope_theta)
    k = rope(k, pos[:, None], dims.rope_theta)
    # scatter the new kv at position pos (indexed update: in-place with
    # donated caches; a one-hot blend would read+write the full cache)
    b_idx = jnp.arange(b, dtype=jnp.int32)
    cache_k = cache_k.at[b_idx, pos].set(k[:, 0])
    cache_v = cache_v.at[b_idx, pos].set(v[:, 0])
    kv_valid = jnp.arange(cache_k.shape[1])[None, :] <= pos[:, None]
    o = attention_scores(
        q, repeat_kv(cache_k, h // kv), repeat_kv(cache_v, h // kv),
        causal=False, kv_valid=kv_valid)
    return o.reshape(b, 1, h * hd) @ p["wo"], (cache_k, cache_v)


# ------------------------------- MLPs -------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    s1 = float(d_model) ** -0.5
    s2 = float(d_ff) ** -0.5
    return {
        "w_gate": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s1,
        "w_up": jax.random.normal(ks[1], (d_model, d_ff), dtype) * s1,
        "w_down": jax.random.normal(ks[2], (d_ff, d_model), dtype) * s2,
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    s1 = float(d_model) ** -0.5
    s2 = float(d_ff) ** -0.5
    return {
        "w_up": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s1,
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": jax.random.normal(ks[1], (d_ff, d_model), dtype) * s2,
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["w_up"] + p["b_up"]) @ p["w_down"] + p["b_down"]
