"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adc(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """ADC estimate: est[n] = sum_m lut[m, codes[n, m]] (squared distance)."""
    take = jax.vmap(lambda row, c: row[c], in_axes=(0, 1), out_axes=1)(
        lut, codes.astype(jnp.int32))
    return jnp.sum(take, axis=1)


def rabitq_est(
    codes: jax.Array,   # (n, d) int8 {-1,+1}
    norm_o: jax.Array,  # (n,)
    f_o: jax.Array,     # (n,)
    v: jax.Array,       # (d,) rotated unit query residual
    norm_q: jax.Array,  # scalar
    eps0: float = 3.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    d = codes.shape[1]
    xv = (codes.astype(jnp.float32) @ v) / jnp.sqrt(jnp.float32(d))
    ip = xv / f_o
    err = eps0 * jnp.sqrt((1.0 - f_o ** 2) / (f_o ** 2 * (d - 1)))
    scale = 2.0 * norm_q * norm_o
    base = norm_q ** 2 + norm_o ** 2
    z = jnp.zeros_like(base)
    est = jnp.sqrt(jnp.maximum(base - scale * ip, z))
    lb = jnp.sqrt(jnp.maximum(base - scale * (ip + err), z))
    ub = jnp.sqrt(jnp.maximum(base - scale * (ip - err), z))
    return est, lb, ub


def bucketize(dists: jax.Array, d_min: jax.Array, delta: jax.Array,
              ew_map: jax.Array, m: int) -> jax.Array:
    """Eq. 6 bucket ids with overflow bucket m."""
    n_ew = ew_map.shape[0]
    bin_id = jnp.floor((dists - d_min) / delta)
    overflow = bin_id >= n_ew
    bin_id = jnp.clip(bin_id, 0, n_ew - 1).astype(jnp.int32)
    bucket = ew_map[bin_id]
    return jnp.where(overflow, m, bucket).astype(jnp.int32)


def bucket_hist(dists: jax.Array, valid: jax.Array, d_min, delta,
                ew_map: jax.Array, m: int) -> tuple[jax.Array, jax.Array]:
    b = bucketize(dists, d_min, delta, ew_map, m)
    w = jnp.where(valid, 1, 0).astype(jnp.int32)
    hist = jnp.zeros((m + 1,), jnp.int32).at[b].add(w)
    return b, hist


def l2_exact(x: jax.Array, q: jax.Array) -> jax.Array:
    """Exact Euclidean distance of rows of x to q."""
    return jnp.sqrt(jnp.maximum(
        jnp.sum(x * x, -1) - 2.0 * (x @ q) + jnp.sum(q * q), 0.0))


# --------------------------------------------------------------------------
# Batched (multi-query) oracles — also the CPU fast path behind ops.*_batch
# --------------------------------------------------------------------------

def pq_adc_batch(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """(n, M) shared codes + (B, M, K) per-query LUTs -> (B, n) squared
    estimates.  Sequential map over queries keeps the (n, M) take
    intermediate B-independent (the batched axis is the LUT, not the codes)."""
    return jax.lax.map(lambda lut: pq_adc(codes, lut), luts)


def bucketize_batch(dists: jax.Array, d_min: jax.Array, delta: jax.Array,
                    ew_maps: jax.Array, m: int) -> jax.Array:
    """(B, n) distances, per-query codebook params -> (B, n) bucket ids."""
    return jax.vmap(bucketize, in_axes=(0, 0, 0, 0, None))(
        dists, d_min, delta, ew_maps, m)


def bucket_hist_batch(dists: jax.Array, valid: jax.Array, d_min, delta,
                      ew_maps: jax.Array, m: int):
    """Batched Eq. 6 + histogram.  Returns (bucket (B, n), hist (B, m+1)).

    The histogram comes from a sort + searchsorted (cumulative counts at
    the bucket edges) rather than a scatter-add: XLA lowers CPU scatters
    to a serial element loop, and on the host-emulated mesh (S shards
    round-robin on one core) that serial cost lands S times per batch —
    the vectorized sort is ~2x faster at bench shapes and bit-identical."""
    bucket = bucketize_batch(dists, d_min, delta, ew_maps, m)
    masked = jnp.where(valid, bucket, m + 1)       # invalid past every edge
    s = jax.lax.sort(masked, dimension=1)
    edges = jnp.arange(m + 1, dtype=jnp.int32)
    cum = jax.vmap(lambda row: jnp.searchsorted(row, edges, side="right"))(s)
    hist = jnp.diff(cum, prepend=0, axis=-1).astype(jnp.int32)
    return bucket, hist


def spec_compact_batch(bucket: jax.Array, valid: jax.Array,
                       tau_spec: jax.Array, budget: int):
    """Stream-order compaction of the lanes at or below ``tau_spec`` into a
    fixed ``budget`` position buffer (the speculative half of the fused
    shard collector).  Returns ``(pos (B, budget) int32 — sentinel n beyond
    the fill, ok (B, budget), count (B,) int32 — the TOTAL matching-lane
    count, possibly above ``budget``: the overflow signal)``."""
    n = bucket.shape[1]
    specm = valid & (bucket <= tau_spec[:, None])
    # stream-order compaction as a sort: matching lanes keep their stream
    # position as the key, everything else sorts past them as the sentinel
    # n — ascending sort + prefix slice IS "first budget matches in stream
    # order", without the serial CPU scatter
    key = jnp.where(specm, jnp.arange(n, dtype=jnp.int32)[None, :], n)
    pos = jax.lax.sort(key, dimension=1)[:, :budget]
    if budget > n:    # static: pad sentinel columns up to the budget width
        pad = jnp.full((bucket.shape[0], budget - n), n, jnp.int32)
        pos = jnp.concatenate([pos, pad], axis=1)
    return pos, pos < n, jnp.sum(specm, axis=1).astype(jnp.int32)


def shard_collect_batch(dists: jax.Array, valid: jax.Array, d_min, delta,
                        ew_maps: jax.Array, m: int, tau_spec: jax.Array,
                        budget: int):
    """Oracle for the fused shard-collect kernel: bucketize + histogram +
    speculative stream-order compaction at the provisional ``tau_spec``
    (-1 compacts nothing).  Returns ``(bucket (B, n), hist (B, m+1),
    spec_pos (B, budget), spec_ok (B, budget), spec_count (B,))``.

    One composite-key sort serves both halves instead of the two
    full-stream sorts of ``bucket_hist_batch`` + ``spec_compact_batch``:
    ``key = masked_bucket * n + lane`` is bucket-major with stream order
    inside each bucket, so cumulative counts at the bucket edges give the
    histogram and — whenever every row's match count fits ``budget`` — the
    sorted prefix holds ALL matching lanes, and a budget-width re-sort by
    lane index restores the exact stream-order buffer the Pallas kernel
    emits.  A row overflowing ``budget`` truncates stream-first, which the
    bucket-major prefix cannot reproduce, so that (rare: the survivor
    tiers discard the buffer anyway) batch falls back to the dedicated
    position sort under a ``cond``.  Requires ``n * (m + 2) < 2**31``."""
    bucket = bucketize_batch(dists, d_min, delta, ew_maps, m)
    bq, n = bucket.shape
    # key max is (m+1)*n + (n-1) < (m+2)*n; past int32 the sort silently
    # corrupts the histogram and buffer, so fail loudly at trace time
    assert n * (m + 2) < 2**31, (
        f"shard_collect_batch composite key overflows int32: "
        f"n={n}, m={m} needs n*(m+2) < 2**31")
    lane = jnp.arange(n, dtype=jnp.int32)[None, :]
    key = jnp.where(valid, bucket, m + 1) * n + lane
    skeys = jax.lax.sort(key, dimension=1)
    edges = (jnp.arange(m + 1, dtype=jnp.int32) + 1) * n
    cum = jax.vmap(
        lambda row: jnp.searchsorted(row, edges, side="left"))(skeys)
    hist = jnp.diff(cum, prepend=0, axis=-1).astype(jnp.int32)
    t = jnp.clip(tau_spec, -1, m).astype(jnp.int32)
    csum = jnp.concatenate(
        [jnp.zeros((bq, 1), jnp.int32), cum.astype(jnp.int32)], axis=1)
    count = jnp.take_along_axis(csum, (t + 1)[:, None], axis=1)[:, 0]

    pw = min(budget, n)

    def fast(_):
        prefix = skeys[:, :pw]
        match = prefix < (t[:, None] + 1) * n
        pos = jax.lax.sort(jnp.where(match, prefix % n, n), dimension=1)
        if budget > n:
            pad = jnp.full((bq, budget - n), n, jnp.int32)
            pos = jnp.concatenate([pos, pad], axis=1)
        return pos

    def slow(_):
        p, _, _ = spec_compact_batch(bucket, valid, tau_spec, budget)
        return p

    pos = jax.lax.cond(jnp.all(count <= budget), fast, slow, None)
    return bucket, hist, pos, pos < n, count


def l2_exact_batch(x: jax.Array, qs: jax.Array) -> jax.Array:
    """(n, d) shared vectors, (B, d) queries -> (B, n) exact distances via
    one norm-identity matmul."""
    x_sq = jnp.sum(x * x, axis=-1)
    q_sq = jnp.sum(qs * qs, axis=-1)
    xv = qs @ x.T
    return jnp.sqrt(jnp.maximum(
        x_sq[None, :] - 2.0 * xv + q_sq[:, None], 0.0))


def fused_scan_batch(
    codes: jax.Array,    # (n, M) shared PQ codes
    vectors: jax.Array,  # (n, d) shared fp32 vectors
    valid: jax.Array,    # (B, n) per-query lane validity
    luts: jax.Array,     # (B, M, K)
    qs: jax.Array,       # (B, d)
    d_min, delta,        # (B,)
    ew_maps: jax.Array,  # (B, n_ew)
    m: int,
    tau_pred: jax.Array, # (B,) int32
):
    """Oracle for the batched fused kernel.

    Returns (est (B, n), bucket (B, n), hist (B, m+1), early (B, n),
    nmiss (B,)) where nmiss counts the valid lanes NOT covered inline
    (bucket > tau_pred) — the upper bound on second-pass gather work."""
    est = jnp.sqrt(jnp.maximum(pq_adc_batch(codes, luts), 0.0))
    est = jnp.where(valid, est, jnp.inf)
    b = bucketize_batch(est, d_min, delta, ew_maps, m)
    w = jnp.where(valid, 1, 0).astype(jnp.int32)
    hist = jax.vmap(
        lambda bb, ww: jnp.zeros((m + 1,), jnp.int32).at[bb].add(ww))(b, w)
    ex = l2_exact_batch(vectors, qs)
    pred = valid & (b <= tau_pred[:, None])
    early = jnp.where(pred, ex, jnp.inf)
    nmiss = jnp.sum(valid & ~pred, axis=1).astype(jnp.int32)
    return est, b, hist, early, nmiss


def rabitq_bounds_stream(codes_s: jax.Array, norm_o: jax.Array,
                         f_o: jax.Array, cl: jax.Array,
                         centroids: jax.Array, rot: jax.Array,
                         qs: jax.Array, d2: jax.Array,
                         lane_valid: jax.Array, eps0: float):
    """Batched RaBitQ estimator over a candidate stream (the CPU production
    bounds pass AND the inner math of the fused-kernel mirror; a shard's
    local stream is just a shorter stream).

    The per-(query, cluster) rotated residual decomposes as
    ``P(q - c) = Pq - Pc``, so the code inner products for every query are
    ONE (n_stream, d) x (d, B) matmul plus a per-lane centroid correction —
    the batched-native form of ``rabitq.query_factors`` + ``estimate``
    (mathematically identical; floating-point association differs from the
    per-cluster matvec of the single-query path).  ``d2`` is the (B, C)
    squared query-centroid distance matrix the routing pass already built;
    ``cl`` maps each stream lane to its (clamped) owning cluster.
    """
    g = qs @ rot.T                                            # (B, d) = Pq
    h = centroids @ rot.T                                     # (C, d) = Pc
    s1 = codes_s @ g.T                                        # (n_stream, B)
    s2 = jnp.sum(codes_s * h[cl], axis=1)                     # (n_stream,)
    nq = jnp.sqrt(d2)                                         # (B, C) norm_q
    nq_lane = nq[:, cl]                                       # (B, n_stream)
    d = codes_s.shape[1]
    xv = (s1.T - s2[None, :]) / (
        jnp.sqrt(jnp.float32(d)) * jnp.maximum(nq_lane, 1e-12))
    ip = xv / f_o[None, :]
    err = eps0 * jnp.sqrt((1.0 - f_o ** 2) / (f_o ** 2 * (d - 1)))
    scale = 2.0 * nq_lane * norm_o[None, :]
    base = nq_lane ** 2 + norm_o[None, :] ** 2
    zero = jnp.zeros_like(base)
    est = jnp.sqrt(jnp.maximum(base - scale * ip, zero))
    lb = jnp.sqrt(jnp.maximum(base - scale * (ip + err[None, :]), zero))
    ub = jnp.sqrt(jnp.maximum(base - scale * (ip - err[None, :]), zero))
    bad = ~lane_valid
    inf = jnp.inf
    return (jnp.where(bad, inf, est), jnp.where(bad, inf, lb),
            jnp.where(bad, inf, ub))


def fused_rabitq_scan_batch(
    codes_s: jax.Array,   # (n, d) ±1 stream codes (fp32)
    vectors: jax.Array,   # (n, d) shared fp32 re-rank vectors
    norm_o: jax.Array,    # (n,)
    f_o: jax.Array,       # (n,)
    cl: jax.Array,        # (n,) clamped owning cluster per lane
    centroids: jax.Array,  # (C, d)
    rot: jax.Array,       # (d, d)
    qs: jax.Array,        # (B, d)
    d2: jax.Array,        # (B, C) squared query-centroid distances
    valid: jax.Array,     # (B, n)
    d_min, delta,         # (B,)
    ew_maps: jax.Array,   # (B, n_ew)
    m: int,
    tau_inline: jax.Array,  # (B,) int32; -1 certifies nothing
    eps0: float = 3.0,
):
    """Oracle for the bound-fused RaBitQ kernel.

    Returns ``(est, lb, ub, bucket_lb, bucket_ub, hist_lb, hist_ub, exact,
    certified, nmiss)`` where ``exact`` carries the inline exact re-rank of
    bound-certified lanes (lower-bound bucket at or below ``tau_inline``)
    and +inf elsewhere, and ``nmiss`` counts the valid lanes the inline
    pass left to the second gather.  ``hist_ub`` is the band anchor (the
    codebook is built from upper bounds) and the cross-batch predictor's
    EMA input; ``hist_lb`` feeds the certain-in threshold.
    """
    est, lb, ub = rabitq_bounds_stream(codes_s, norm_o, f_o, cl, centroids,
                                       rot, qs, d2, valid, eps0)
    bucket_lb = bucketize_batch(lb, d_min, delta, ew_maps, m)
    bucket_ub = bucketize_batch(ub, d_min, delta, ew_maps, m)
    w = jnp.where(valid, 1, 0).astype(jnp.int32)
    hist = jax.vmap(
        lambda bb, ww: jnp.zeros((m + 1,), jnp.int32).at[bb].add(ww))
    hist_lb = hist(bucket_lb, w)
    hist_ub = hist(bucket_ub, w)
    ex = l2_exact_batch(vectors, qs)
    certified = valid & (bucket_lb <= tau_inline[:, None])
    exact = jnp.where(certified, ex, jnp.inf)
    nmiss = jnp.sum(valid & ~certified, axis=1).astype(jnp.int32)
    return (est, lb, ub, bucket_lb, bucket_ub, hist_lb, hist_ub, exact,
            certified, nmiss)


def fused_rabitq_scan(codes_s, vectors, norm_o, f_o, cl, centroids, rot,
                      q, d2, valid, d_min, delta, ew_map, m, tau_inline,
                      eps0: float = 3.0):
    """Single-query oracle: the batched mirror on a singleton batch."""
    outs = fused_rabitq_scan_batch(
        codes_s, vectors, norm_o, f_o, cl, centroids, rot, q[None],
        d2[None], valid[None], d_min[None], delta[None], ew_map[None], m,
        jnp.asarray(tau_inline, jnp.int32)[None], eps0)
    return tuple(o[0] for o in outs)


def fused_scan(
    codes: jax.Array,    # (n, M) uint8/int32 PQ codes
    vectors: jax.Array,  # (n, d) fp32
    valid: jax.Array,    # (n,)
    lut: jax.Array,      # (M, K)
    q: jax.Array,        # (d,)
    d_min, delta,
    ew_map: jax.Array,   # (n_ew,)
    m: int,
    tau_pred: jax.Array, # scalar int32
):
    """Oracle for the fused estimate+bucketize+hist+early-exact kernel.

    Returns (est, bucket, hist, early_exact, nmiss) where early_exact[i] is
    the exact distance when bucket[i] <= tau_pred (and valid), else +inf, and
    nmiss is the scalar count of valid lanes not covered inline.
    """
    est2 = pq_adc(codes, lut)
    est = jnp.sqrt(jnp.maximum(est2, 0.0))
    est = jnp.where(valid, est, jnp.inf)
    b = bucketize(est, d_min, delta, ew_map, m)
    w = jnp.where(valid, 1, 0).astype(jnp.int32)
    hist = jnp.zeros((m + 1,), jnp.int32).at[b].add(w)
    ex = l2_exact(vectors, q)
    pred = valid & (b <= tau_pred)
    early = jnp.where(pred, ex, jnp.inf)
    nmiss = jnp.sum(valid & ~pred).astype(jnp.int32)
    return est, b, hist, early, nmiss
