"""Pallas TPU kernel: bound-fused RaBitQ scan (estimate + bounds + bucketize
+ histogram + bound-certified inline exact re-rank).

This is the RaBitQ counterpart of ``fused_scan.py``'s Alg.-4 kernel — the
paper's second re-ranking algorithm executed, not modeled.  A two-phase
RaBitQ search streams the candidate block once for estimates/bounds and then
gathers the uncertain band a second time for the exact re-rank; at large k
that second gather dominates (the cache-miss cost the paper's Table 2
counts).  The fused kernel streams the ±1 code block AND the fp32 vector
block of a cluster tile through VMEM together and, per tile, produces

    est/lb/ub   — the RaBitQ estimator with its error bounds (the batched
                  ``P(q-c) = Pq - Pc`` decomposition: one (TILE, d) x (d, B)
                  MXU matmul against the rotated queries plus a per-lane
                  centroid correction ``s2`` that is query-independent),
    bucket_lb / bucket_ub — Eq. 6 bucket ids of both bounds against the
                  per-query codebook (one-hot LUT, shared helper with the
                  PQ kernel),
    hist_lb / hist_ub — (m+1)-histograms of both bounds, accumulated across
                  the grid (VMEM-resident; hist_ub anchors the band
                  threshold and the cross-batch predictor's EMA),
    exact       — exact ||q - x|| for lanes whose LOWER-bound bucket is at
                  or below ``tau_inline`` (the bound-certified inline band),
                  +inf elsewhere — computed while the vector tile is
                  VMEM-resident, so certified lanes never pay the second
                  gather,
    certified   — the inline-coverage mask itself,
    nmiss       — per-query count of valid lanes NOT covered inline (the
                  upper bound on second-pass gather work; the searcher's
                  measured ``n_second_pass`` is the band ∩ ~certified
                  subset of these).

``tau_inline`` is per query: the predictive path passes the engine's EMA
``tau_pred`` (-1 while cold — nothing certified, everything falls through
to the gather, exactly like the static two-phase path), the static path
passes the sample-prefix rank-scaled threshold (Alg. 4 line 4 applied to
the k-th upper bound).

VMEM working set at defaults (TILE=256, d<=1536, B<=32, n_ew=256):
  codes + vectors 2 * 256*1536*4 = 3 MiB, per-lane factors < 16 KiB,
  (TILE, B) masks/outputs ~ 8 * 32 KiB, LUTs + scalars < 64 KiB -> ~3.4 MiB,
comfortably inside ~16 MiB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.fused_scan import bucketize_hist_tile
from repro.kernels.platform import resolve_interpret

TILE = 256
BQ = 8   # query-batch chunk width inside the bucketize/hist helper


def _rabitq_fused_batch_kernel(codes_ref, vecs_ref, s2_ref, norm_ref, f_ref,
                               wmask_ref, nq_ref, g_ref, qt_ref, ew_ref,
                               scal_ref, est_ref, lb_ref, ub_ref, blb_ref,
                               bub_ref, exact_ref, cert_ref, hist_lb_ref,
                               hist_ub_ref, nmiss_ref, *, m: int,
                               hist_pad: int, bq: int, eps0: float,
                               sqrt_d: float, dm1: float):
    codes = codes_ref[...].astype(jnp.float32)    # (TILE, d) ±1
    vecs = vecs_ref[...]                          # (TILE, d)
    s2 = s2_ref[...][0]                           # (TILE,) codes · Pc[cl]
    no = norm_ref[...][0]                         # (TILE,)
    fo = f_ref[...][0]                            # (TILE,)
    w = wmask_ref[...]                            # (TILE, B) int32
    nq = nq_ref[...]                              # (TILE, B) ||q - c[lane]||
    g = g_ref[...]                                # (d, B) rotated queries Pq
    qt = qt_ref[...]                              # (d, B) raw queries
    ew = ew_ref[...]                              # (B, n_ew)
    s = scal_ref[...]                             # (B, 128)
    d_min, delta = s[:, 0], s[:, 1]
    tau_inline = s[:, 2].astype(jnp.int32)
    q_sq = s[:, 3]
    tile, b = w.shape
    inf = jnp.float32(jnp.inf)

    # --- RaBitQ estimator + bounds: one MXU matmul for all B queries ---
    s1 = jax.lax.dot_general(codes, g, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (TILE, B)
    xv = (s1 - s2[:, None]) / (sqrt_d * jnp.maximum(nq, 1e-12))
    ip = xv / fo[:, None]
    err = eps0 * jnp.sqrt((1.0 - fo * fo) / (fo * fo * dm1))      # (TILE,)
    scale = 2.0 * nq * no[:, None]
    base = nq * nq + no[:, None] * no[:, None]
    zero = jnp.zeros_like(base)
    live = w > 0
    est = jnp.sqrt(jnp.maximum(base - scale * ip, zero))
    lb = jnp.sqrt(jnp.maximum(base - scale * (ip + err[:, None]), zero))
    ub = jnp.sqrt(jnp.maximum(base - scale * (ip - err[:, None]), zero))
    est = jnp.where(live, est, inf)
    lb = jnp.where(live, lb, inf)
    ub = jnp.where(live, ub, inf)
    est_ref[...] = est
    lb_ref[...] = lb
    ub_ref[...] = ub

    # --- bucketize both bounds + per-query histograms ---
    bucket_lb, tile_hist_lb = bucketize_hist_tile(lb, w, ew, d_min, delta, m,
                                                  hist_pad, bq)
    bucket_ub, tile_hist_ub = bucketize_hist_tile(ub, w, ew, d_min, delta, m,
                                                  hist_pad, bq)
    blb_ref[...] = bucket_lb
    bub_ref[...] = bucket_ub

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_lb_ref[...] = jnp.zeros_like(hist_lb_ref)
        hist_ub_ref[...] = jnp.zeros_like(hist_ub_ref)
        nmiss_ref[...] = jnp.zeros_like(nmiss_ref)

    hist_lb_ref[...] += tile_hist_lb
    hist_ub_ref[...] += tile_hist_ub

    # --- bound-certified inline exact: vectors are already in VMEM ---
    xq = jax.lax.dot_general(vecs, qt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (TILE, B)
    x_sq = jnp.sum(vecs * vecs, axis=1)
    exact = jnp.sqrt(jnp.maximum(
        x_sq[:, None] - 2.0 * xq + q_sq[None, :], 0.0))
    cert = live & (bucket_lb <= tau_inline[None, :])
    exact_ref[...] = jnp.where(cert, exact, inf)
    cert_ref[...] = cert.astype(jnp.int32)

    # --- per-query miss counts (lanes left to the second gather pass) ---
    cnt = jnp.sum((live & ~cert).astype(jnp.int32), axis=0)       # (B,)
    miota = jax.lax.broadcasted_iota(jnp.int32, (b, 128), 1)
    nmiss_ref[...] += jnp.where(miota == 0, cnt[:, None], 0)


def fused_rabitq_scan_batch_pallas(
    codes: jax.Array,      # (n, d) int8 ±1, n % tile == 0, d lane-padded
    vectors: jax.Array,    # (n, d) fp32 — co-tiled re-rank source
    s2: jax.Array,         # (n,) query-independent centroid correction
    norm_o: jax.Array,     # (n,)
    f_o: jax.Array,        # (n,)
    valid: jax.Array,      # (n, B) bool per-query lane validity
    nq_lane: jax.Array,    # (n, B) per-lane query-centroid norms
    g: jax.Array,          # (B, d) rotated queries (qs @ rot.T)
    qs: jax.Array,         # (B, d) raw queries (for the exact re-rank)
    d_min: jax.Array,      # (B,)
    delta: jax.Array,      # (B,)
    ew_maps: jax.Array,    # (B, n_ew) int32
    m: int,
    tau_inline: jax.Array,  # (B,) int32; -1 certifies nothing
    d_logical: int,
    eps0: float = 3.0,
    tile: int = TILE,
    bq: int = BQ,
    interpret: bool | None = None,
):
    """Batched bound-fused RaBitQ scan over a shared candidate stream.

    Returns ``(est, lb, ub, bucket_lb, bucket_ub, hist_lb, hist_ub, exact,
    certified, nmiss)`` with (B, n) lane tensors, (B, m+1) histograms and
    (B,) miss counts.  Requires B % bq == 0 (wrappers pad the query batch).
    """
    interpret = resolve_interpret(interpret)
    n, d = codes.shape
    b = qs.shape[0]
    assert b % bq == 0, (b, bq)
    g_tiles = n // tile
    n_ew = ew_maps.shape[1]
    hist_pad = ((m + 1 + 127) // 128) * 128
    scal = jnp.zeros((b, 128), jnp.float32)
    scal = scal.at[:, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[:, 1].set(delta.astype(jnp.float32))
    scal = scal.at[:, 2].set(tau_inline.astype(jnp.float32))
    scal = scal.at[:, 3].set(jnp.sum(qs * qs, axis=1))
    w = valid.astype(jnp.int32)                                   # (n, B)
    lane_f32 = jax.ShapeDtypeStruct((n, b), jnp.float32)
    lane_i32 = jax.ShapeDtypeStruct((n, b), jnp.int32)
    lane_spec = pl.BlockSpec((tile, b), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(
            _rabitq_fused_batch_kernel, m=m, hist_pad=hist_pad, bq=bq,
            eps0=eps0, sqrt_d=float(np.float32(math.sqrt(d_logical))),
            dm1=float(d_logical - 1)),
        grid=(g_tiles,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),     # codes
            pl.BlockSpec((tile, d), lambda i: (i, 0)),     # vectors
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # s2
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # norm_o
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # f_o
            lane_spec,                                     # valid
            lane_spec,                                     # nq_lane
            pl.BlockSpec((d, b), lambda i: (0, 0)),        # g
            pl.BlockSpec((d, b), lambda i: (0, 0)),        # qs
            pl.BlockSpec((b, n_ew), lambda i: (0, 0)),     # ew_maps
            pl.BlockSpec((b, 128), lambda i: (0, 0)),      # scal
        ],
        out_specs=[
            lane_spec, lane_spec, lane_spec,               # est, lb, ub
            lane_spec, lane_spec,                          # bucket_lb/ub
            lane_spec, lane_spec,                          # exact, certified
            pl.BlockSpec((b, hist_pad), lambda i: (0, 0)),
            pl.BlockSpec((b, hist_pad), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_shape=[
            lane_f32, lane_f32, lane_f32,
            lane_i32, lane_i32,
            lane_f32, lane_i32,
            jax.ShapeDtypeStruct((b, hist_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, hist_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, 128), jnp.int32),
        ],
        interpret=interpret,
    )(codes, vectors, s2.reshape(1, n), norm_o.reshape(1, n),
      f_o.reshape(1, n), w, nq_lane, g.T, qs.T,
      ew_maps.astype(jnp.int32), scal)
    est, lb, ub, blb, bub, exact, cert, hist_lb, hist_ub, nmiss = outs
    return (est.T, lb.T, ub.T, blb.T, bub.T, hist_lb[:, : m + 1],
            hist_ub[:, : m + 1], exact.T, cert.T.astype(bool), nmiss[:, 0])
