"""Platform detection shared by the kernel modules and their ops wrappers.

Kept in its own module (rather than ops.py) so the kernel files can resolve
their ``interpret`` default without a circular import: ops imports the kernel
modules, and the kernel modules import only this.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def default_interpret() -> bool:
    """Pallas ``interpret`` default: compile to Mosaic on TPU, run the Python
    interpreter path everywhere else (CPU containers, CI)."""
    return not on_tpu()


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else interpret
