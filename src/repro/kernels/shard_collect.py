"""Pallas TPU kernel: fused shard scan-collect — bucketize (Eq. 6) +
(m+1)-histogram + *speculative* survivor compaction in one stream pass.

The sharded deployment's collector used to be three passes over the local
stream: bucketize+histogram (fused), then — after the psum round-trip — a
full-stream masked ``top_k`` to compact survivors into the fixed per-shard
budget.  That post-hoc compaction re-reads the whole (B, F) stream from HBM
and its sort is the single most expensive per-shard stage at large k.

This kernel removes it: while each distance tile is resident it ALSO
compacts the lanes at or below a *provisional* threshold ``tau_spec`` (the
engine's tau_pred, or the sample-derived seed) into a budget-sized position
buffer, in stream order, with the running per-query fill count as the only
extra cross-tile state.  After the psum, the true tau is compared against
``tau_spec``:

  * covered  (tau_spec >= tau, buffer not overflowed): the speculative
    buffer is filtered down to tau — no second stream pass at all;
  * undershoot: one bounded O(F) cumsum-compaction correction pass;
  * overflow: the exact key-priority ``top_k`` fallback.

(The tiering lives in ``core.distributed.bbc_survivors_batch``; this module
only produces the buffer.)  ``tau_spec = -1`` compacts nothing — the cold
path degrades to exactly the old behavior.

Compaction inside the kernel: per tile the masked lanes' prefix sums give
their slots; a (tile, tile) slot==prefix one-hot reduce scatters the global
lane positions into a compacted (tile,) vector (each slot matches at most
one lane), which is written at the buffer's current fill offset with a
dynamic lane-window store.  The buffer is ``budget + tile`` wide so a
partially-filling window never clips; empty window tails hold the sentinel
``n_pad`` and are overwritten by the next tile's window.

Grid accumulation (histogram, fill counts, buffer) relies on Pallas TPU
grids iterating sequentially on a core, exactly like bucket_hist.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_scan import bucketize_hist_tile
from repro.kernels.platform import resolve_interpret

TILE = 256
BQ = 8   # query-batch chunk width inside the bucketize helper


def _compact_tile(bucket, w, tau_spec, spec_ref, cnt_ref, budget: int,
                  n_pad: int):
    """Append this tile's at-or-below-``tau_spec`` lanes to the resident
    survivor buffer, in stream order.  ``bucket``/``w`` are (tile, b);
    ``spec_ref`` is the (b, budget + tile) position buffer, ``cnt_ref`` the
    (b, 128) running fill counts (col 0; kept as the TRUE unclamped totals
    so the wrapper can report them — only the write offset clamps)."""
    tile, b = bucket.shape
    specm = (w > 0) & (bucket <= tau_spec[None, :])
    mi = specm.astype(jnp.int32)
    pref = jnp.cumsum(mi, axis=0) - 1                        # (tile, b)
    tile_counts = jnp.sum(mi, axis=0)                        # (b,)
    gpos = pl.program_id(0) * tile + jax.lax.broadcasted_iota(
        jnp.int32, (tile, 1), 0)[:, 0]                       # (tile,)
    sio = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0)
    for q in range(b):
        slots_q = jnp.where(specm[:, q], pref[:, q], tile)   # (tile,)
        eq = sio == slots_q[None, :]                         # eq[slot, lane]
        compact = jnp.sum(jnp.where(eq, gpos[None, :], 0), axis=1)
        filled = jnp.sum(eq.astype(jnp.int32), axis=1)
        compact = jnp.where(filled > 0, compact, n_pad)
        off = jnp.minimum(cnt_ref[q, 0], budget)
        spec_ref[q, pl.ds(off, tile)] = compact
    cio = jax.lax.broadcasted_iota(jnp.int32, (b, 128), 1)
    cnt_ref[...] += jnp.where(cio == 0, tile_counts[:, None], 0)


def _collect_batch_kernel(dists_ref, wmask_ref, ew_ref, scal_ref,
                          bucket_ref, hist_ref, spec_ref, cnt_ref,
                          *, m: int, hist_pad: int, bq: int, budget: int,
                          n_pad: int):
    d = dists_ref[...]                           # (TILE, B)
    w = wmask_ref[...]                           # (TILE, B) int32
    ew = ew_ref[...]                             # (B, n_ew)
    s = scal_ref[...]                            # (B, 128)
    d_min, delta = s[:, 0], s[:, 1]
    tau_spec = s[:, 2].astype(jnp.int32)         # (B,) exact in fp32

    bucket, tile_hist = bucketize_hist_tile(d, w, ew, d_min, delta, m,
                                            hist_pad, bq)
    bucket_ref[...] = bucket

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        spec_ref[...] = jnp.full_like(spec_ref, n_pad)

    hist_ref[...] += tile_hist
    _compact_tile(bucket, w, tau_spec, spec_ref, cnt_ref, budget, n_pad)


def shard_collect_batch_pallas(
    dists: jax.Array,    # (B, n) fp32, n % tile == 0 (invalid lanes = +inf)
    valid: jax.Array,    # (B, n) bool
    d_min: jax.Array,    # (B,)
    delta: jax.Array,    # (B,)
    ew_maps: jax.Array,  # (B, n_ew) int32
    m: int,
    tau_spec: jax.Array,  # (B,) int32; -1 compacts nothing
    budget: int,
    tile: int = TILE,
    bq: int = BQ,
    interpret: bool | None = None,
):
    """Fused bucketize + histogram + speculative compaction.

    Returns ``(bucket (B, n), hist (B, m+1), spec_pos (B, budget),
    spec_count (B,))``; ``spec_pos`` holds stream positions of the first
    ``budget`` lanes with bucket <= tau_spec in stream order (sentinel
    ``n`` beyond the fill), ``spec_count`` the TOTAL matching-lane count
    (may exceed ``budget`` — the overflow signal).  Requires B % bq == 0.
    """
    interpret = resolve_interpret(interpret)
    b, n = dists.shape
    assert b % bq == 0, (b, bq)
    g = n // tile
    n_ew = ew_maps.shape[1]
    hist_pad = ((m + 1 + 127) // 128) * 128
    bud_pad = ((budget + 127) // 128) * 128
    spec_w = bud_pad + tile
    scal = jnp.zeros((b, 128), jnp.float32)
    scal = scal.at[:, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[:, 1].set(delta.astype(jnp.float32))
    scal = scal.at[:, 2].set(tau_spec.astype(jnp.float32))
    w = valid.astype(jnp.int32).T                 # (n, B)
    bucket, hist, spec, cnt = pl.pallas_call(
        functools.partial(_collect_batch_kernel, m=m, hist_pad=hist_pad,
                          bq=bq, budget=bud_pad, n_pad=n),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((b, n_ew), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((b, hist_pad), lambda i: (0, 0)),
            pl.BlockSpec((b, spec_w), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, b), jnp.int32),
            jax.ShapeDtypeStruct((b, hist_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, spec_w), jnp.int32),
            jax.ShapeDtypeStruct((b, 128), jnp.int32),
        ],
        interpret=interpret,
    )(dists.T, w, ew_maps.astype(jnp.int32), scal)
    return bucket.T, hist[:, : m + 1], spec[:, :budget], cnt[:, 0]


def _compact_only_kernel(bucket_ref, wmask_ref, taus_ref, spec_ref, cnt_ref,
                         *, budget: int, n_pad: int):
    bucket = bucket_ref[...]                     # (TILE, B)
    w = wmask_ref[...]                           # (TILE, B) int32
    tau_spec = taus_ref[...][:, 0]               # (B,)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        spec_ref[...] = jnp.full_like(spec_ref, n_pad)

    _compact_tile(bucket, w, tau_spec, spec_ref, cnt_ref, budget, n_pad)


def spec_compact_batch_pallas(
    bucket: jax.Array,   # (B, n) int32, n % tile == 0
    valid: jax.Array,    # (B, n) bool
    tau_spec: jax.Array,  # (B,) int32
    budget: int,
    tile: int = TILE,
    interpret: bool | None = None,
):
    """Compaction-only form for scans whose bucket ids already exist (the
    bound-fused RaBitQ kernel emits bucket_lb itself).  Same buffer
    contract as ``shard_collect_batch_pallas``; returns (spec_pos
    (B, budget), spec_count (B,))."""
    interpret = resolve_interpret(interpret)
    b, n = bucket.shape
    g = n // tile
    bud_pad = ((budget + 127) // 128) * 128
    spec_w = bud_pad + tile
    taus = jnp.broadcast_to(tau_spec.astype(jnp.int32)[:, None],
                            (b, 128))
    w = valid.astype(jnp.int32).T
    spec, cnt = pl.pallas_call(
        functools.partial(_compact_only_kernel, budget=bud_pad, n_pad=n),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, spec_w), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, spec_w), jnp.int32),
            jax.ShapeDtypeStruct((b, 128), jnp.int32),
        ],
        interpret=interpret,
    )(bucket.T, w, taus)
    return spec[:, :budget], cnt[:, 0]
