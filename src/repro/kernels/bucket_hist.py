"""Pallas TPU kernel: bucketize (Eq. 6) + m-histogram accumulation.

The paper's result-buffer Push is per-object append + threshold compare; the
TPU version streams distance tiles and keeps the (m+1)-histogram as the ONLY
cross-tile state, resident in VMEM for the whole grid (the L1-residency
analogue).  The equal-width -> equal-depth LUT (256 uint8 entries on CPU) is a
256-lane VMEM vector here, applied by one-hot matmul (gathers are slow on
TPU; 256-wide one-hot fits the MXU exactly).

Grid accumulation: the histogram output block maps to (0, 0) on every step;
step 0 initializes, later steps accumulate — Pallas TPU grids iterate
sequentially on a core, so this is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_scan import bucketize_hist_tile
from repro.kernels.platform import resolve_interpret

TILE = 512
BQ = 8   # query-batch chunk width inside the batched kernel


def _bucket_kernel(dists_ref, wmask_ref, ew_map_ref, scal_ref,
                   bucket_ref, hist_ref, *, m: int, hist_pad: int):
    d = dists_ref[...][0]                        # (TILE,)
    w = wmask_ref[...][0]                        # (TILE,) int32
    ew = ew_map_ref[...]                         # (1, n_ew) int32
    s = scal_ref[...]
    d_min, delta = s[0, 0], s[0, 1]
    n_ew = ew.shape[1]
    tile = d.shape[0]

    bin_f = jnp.floor((d - d_min) / delta)
    overflow = bin_f >= n_ew
    bin_id = jnp.clip(bin_f, 0, n_ew - 1).astype(jnp.int32)
    # LUT via one-hot matmul (256-wide).
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile, n_ew), 1)
    onehot = (iota == bin_id[:, None]).astype(jnp.float32)
    bucket = jax.lax.dot_general(
        onehot, ew.reshape(n_ew, 1).astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0].astype(jnp.int32)
    bucket = jnp.where(overflow, m, bucket)
    bucket_ref[...] = bucket[None, :]

    # Histogram of this tile (weighted by validity), accumulated across grid.
    hiota = jax.lax.broadcasted_iota(jnp.int32, (tile, hist_pad), 1)
    hoh = jnp.where(hiota == bucket[:, None], w[:, None], 0)
    tile_hist = jnp.sum(hoh, axis=0, dtype=jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += tile_hist[None, :]


def bucket_hist_pallas(
    dists: jax.Array,    # (n,) fp32, n % tile == 0 (invalid lanes = +inf)
    valid: jax.Array,    # (n,) bool
    d_min: jax.Array,
    delta: jax.Array,
    ew_map: jax.Array,   # (n_ew,) int32
    m: int,
    tile: int = TILE,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (bucket_ids (n,), hist (m+1,))."""
    interpret = resolve_interpret(interpret)
    n = dists.shape[0]
    g = n // tile
    n_ew = ew_map.shape[0]
    hist_pad = ((m + 1 + 127) // 128) * 128
    scal = jnp.zeros((1, 128), jnp.float32)
    scal = scal.at[0, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[0, 1].set(delta.astype(jnp.float32))
    w = valid.astype(jnp.int32)
    bucket, hist = pl.pallas_call(
        functools.partial(_bucket_kernel, m=m, hist_pad=hist_pad),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, n_ew), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, hist_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, tile), jnp.int32),
            jax.ShapeDtypeStruct((1, hist_pad), jnp.int32),
        ],
        interpret=interpret,
    )(dists.reshape(1, n), w.reshape(1, n), ew_map.reshape(1, n_ew), scal)
    return bucket.reshape(n), hist[0, : m + 1]


# --------------------------------------------------------------------------
# Batched (multi-query) bucketize + histogram
# --------------------------------------------------------------------------

def _bucket_batch_kernel(dists_ref, wmask_ref, ew_ref, scal_ref,
                         bucket_ref, hist_ref, *, m: int, hist_pad: int,
                         bq: int):
    d = dists_ref[...]                           # (TILE, B)
    w = wmask_ref[...]                           # (TILE, B)
    ew = ew_ref[...]                             # (B, n_ew)
    s = scal_ref[...]                            # (B, 128)
    d_min, delta = s[:, 0], s[:, 1]

    bucket, tile_hist = bucketize_hist_tile(d, w, ew, d_min, delta, m,
                                            hist_pad, bq)
    bucket_ref[...] = bucket

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += tile_hist


def bucket_hist_batch_pallas(
    dists: jax.Array,    # (B, n) fp32, n % tile == 0 (invalid lanes = +inf)
    valid: jax.Array,    # (B, n) bool
    d_min: jax.Array,    # (B,)
    delta: jax.Array,    # (B,)
    ew_maps: jax.Array,  # (B, n_ew) int32
    m: int,
    tile: int = TILE,
    bq: int = BQ,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched Eq. 6 + histogram: per-query codebooks over a (B, n) distance
    matrix, one (B, m+1) histogram as the only cross-tile state.

    Returns (bucket_ids (B, n), hist (B, m+1)).  Requires B % bq == 0
    (wrappers pad the query batch).
    """
    interpret = resolve_interpret(interpret)
    b, n = dists.shape
    assert b % bq == 0, (b, bq)
    g = n // tile
    n_ew = ew_maps.shape[1]
    hist_pad = ((m + 1 + 127) // 128) * 128
    scal = jnp.zeros((b, 128), jnp.float32)
    scal = scal.at[:, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[:, 1].set(delta.astype(jnp.float32))
    w = valid.astype(jnp.int32).T                 # (n, B)
    bucket, hist = pl.pallas_call(
        functools.partial(_bucket_batch_kernel, m=m, hist_pad=hist_pad,
                          bq=bq),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((b, n_ew), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((b, hist_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, b), jnp.int32),
            jax.ShapeDtypeStruct((b, hist_pad), jnp.int32),
        ],
        interpret=interpret,
    )(dists.T, w, ew_maps.astype(jnp.int32), scal)
    return bucket.T, hist[:, : m + 1]
