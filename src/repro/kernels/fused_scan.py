"""Pallas TPU kernel: fused estimate + bucketize + histogram + early exact.

This is the flagship kernel — the TPU-native realization of the paper's
Algorithm 4 (early re-ranking).  On CPU the paper co-locates PQ codes with the
fp32 vector and computes the exact distance "while the data is hot in cache".
On TPU the analogue is HBM-traffic fusion: one pass streams the code block AND
the vector block of a cluster tile through VMEM and produces

    est    — ADC estimate (one-hot matmul, see pq_adc.py),
    bucket — Eq. 6 bucket id (one-hot LUT),
    hist   — (m+1)-histogram accumulated across the grid (VMEM-resident),
    early  — exact ||q - x|| for lanes whose bucket <= tau_pred, else +inf,

eliminating the second gather pass over the re-rank pool (the cache-miss /
HBM-re-read saving of Table 2).  Exact distances are computed for all lanes
of the tile and masked — TPUs prefer redundant lanes over divergence; the
saving is memory traffic, not FLOPs.

VMEM working set at defaults (TILE=256, d<=1536, M<=384, K=16):
  vectors block 256*1536*4 = 1.5 MiB, codes 256*384*4 = 384 KiB,
  one-hot chunk 256*32*16*4 = 512 KiB, LUT + maps < 64 KiB  -> ~2.5 MiB,
comfortably inside ~16 MiB VMEM; m (Eq. 3') can stay in the hundreds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret

TILE = 256
MC = 32
BQ = 8   # query-batch chunk width inside the batched kernels


def _fused_kernel(codes_ref, vecs_ref, wmask_ref, lut_ref, qv_ref, ew_map_ref,
                  scal_ref, est_ref, bucket_ref, early_ref, hist_ref,
                  nmiss_ref, *, m: int, hist_pad: int, mc: int):
    codes = codes_ref[...].astype(jnp.int32)      # (TILE, M)
    vecs = vecs_ref[...]                          # (TILE, d)
    w = wmask_ref[...][0]                         # (TILE,)
    lut = lut_ref[...]                            # (M, K)
    qv = qv_ref[...]                              # (1, d)
    ew = ew_map_ref[...]                          # (1, n_ew)
    s = scal_ref[...]
    d_min, delta, q_sq = s[0, 0], s[0, 1], s[0, 3]
    tau_pred = s[0, 2].astype(jnp.int32)
    tile, m_sub = codes.shape
    k_codes = lut.shape[1]
    n_ew = ew.shape[1]
    inf = jnp.float32(jnp.inf)

    # --- ADC estimate (chunked one-hot matmul) ---
    def body(i, acc):
        cs = jax.lax.dynamic_slice_in_dim(codes, i * mc, mc, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(lut, i * mc, mc, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, mc, k_codes), 2)
        onehot = (iota == cs[:, :, None]).astype(ls.dtype)
        part = jax.lax.dot_general(
            onehot.reshape(tile, mc * k_codes), ls.reshape(mc * k_codes, 1),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return acc + part[:, 0]

    est2 = jax.lax.fori_loop(0, m_sub // mc, body,
                             jnp.zeros((tile,), jnp.float32))
    est = jnp.sqrt(jnp.maximum(est2, 0.0))
    est = jnp.where(w > 0, est, inf)
    est_ref[...] = est[None, :]

    # --- bucketize (Eq. 6, one-hot LUT) ---
    bin_f = jnp.floor((est - d_min) / delta)
    overflow = bin_f >= n_ew
    bin_id = jnp.clip(bin_f, 0, n_ew - 1).astype(jnp.int32)
    iota2 = jax.lax.broadcasted_iota(jnp.int32, (tile, n_ew), 1)
    onehot2 = (iota2 == bin_id[:, None]).astype(jnp.float32)
    bucket = jax.lax.dot_general(
        onehot2, ew.reshape(n_ew, 1).astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )[:, 0].astype(jnp.int32)
    bucket = jnp.where(overflow, m, bucket)
    bucket_ref[...] = bucket[None, :]

    # --- histogram accumulation (the only cross-tile state) ---
    hiota = jax.lax.broadcasted_iota(jnp.int32, (tile, hist_pad), 1)
    tile_hist = jnp.sum(
        jnp.where(hiota == bucket[:, None], w[:, None], 0), axis=0,
        dtype=jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        nmiss_ref[...] = jnp.zeros_like(nmiss_ref)

    hist_ref[...] += tile_hist[None, :]

    # --- early exact re-rank (Alg. 4): vectors are already in VMEM ---
    xv = jax.lax.dot_general(
        vecs, qv.reshape(-1, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    x_sq = jnp.sum(vecs * vecs, axis=1)
    exact = jnp.sqrt(jnp.maximum(x_sq - 2.0 * xv + q_sq, 0.0))
    pred = (w > 0) & (bucket <= tau_pred)
    early_ref[...] = jnp.where(pred, exact, inf)[None, :]

    # --- miss count: valid lanes the prediction left to the second pass ---
    cnt = jnp.sum(((w > 0) & ~pred).astype(jnp.int32))
    miota = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
    nmiss_ref[...] += jnp.where(miota == 0, cnt, 0)


def fused_scan_pallas(
    codes: jax.Array,     # (n, M) int32/uint8, n % tile == 0, M % mc == 0
    vectors: jax.Array,   # (n, d) fp32
    valid: jax.Array,     # (n,) bool
    lut: jax.Array,       # (M, K) fp32
    q: jax.Array,         # (d,) fp32
    d_min: jax.Array,
    delta: jax.Array,
    ew_map: jax.Array,    # (n_ew,) int32
    m: int,
    tau_pred: jax.Array,  # scalar int32
    tile: int = TILE,
    mc: int = MC,
    interpret: bool | None = None,
):
    """Returns (est (n,), bucket (n,), hist (m+1,), early (n,), nmiss ())."""
    interpret = resolve_interpret(interpret)
    n, m_sub = codes.shape
    d = vectors.shape[1]
    g = n // tile
    n_ew = ew_map.shape[0]
    hist_pad = ((m + 1 + 127) // 128) * 128
    scal = jnp.zeros((1, 128), jnp.float32)
    scal = scal.at[0, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[0, 1].set(delta.astype(jnp.float32))
    scal = scal.at[0, 2].set(tau_pred.astype(jnp.float32))
    scal = scal.at[0, 3].set(jnp.sum(q * q))
    w = valid.astype(jnp.int32)
    est, bucket, early, hist, nmiss = pl.pallas_call(
        functools.partial(_fused_kernel, m=m, hist_pad=hist_pad, mc=mc),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, m_sub), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, n_ew), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, hist_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, tile), jnp.float32),
            jax.ShapeDtypeStruct((g, tile), jnp.int32),
            jax.ShapeDtypeStruct((g, tile), jnp.float32),
            jax.ShapeDtypeStruct((1, hist_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, 128), jnp.int32),
        ],
        interpret=interpret,
    )(codes, vectors, w.reshape(1, n), lut, q.reshape(1, d),
      ew_map.reshape(1, n_ew), scal)
    return (est.reshape(n), bucket.reshape(n), hist[0, : m + 1],
            early.reshape(n), nmiss[0, 0])


# --------------------------------------------------------------------------
# Batched (multi-query) fused scan
# --------------------------------------------------------------------------

def bucketize_hist_tile(est, w, ew, d_min, delta, m, hist_pad, bq):
    """Shared per-tile bucketize + histogram for the batched kernels.

    ``est`` (tile, B) distances, ``w`` (tile, B) int32 validity, ``ew``
    (B, n_ew) equal-width -> equal-depth LUTs, ``d_min``/``delta`` (B,).
    Returns (bucket (tile, B) int32, hist (B, hist_pad) int32).  The one-hot
    LUT application and histogram are chunked over the query axis in blocks
    of ``bq`` so the (tile, bq, n_ew) intermediates stay VMEM-sized.
    """
    tile, b = est.shape
    n_ew = ew.shape[1]
    bin_f = jnp.floor((est - d_min[None, :]) / delta[None, :])
    overflow = bin_f >= n_ew
    bin_id = jnp.clip(bin_f, 0, n_ew - 1).astype(jnp.int32)

    def map_chunk(j, bucket):
        bc = jax.lax.dynamic_slice_in_dim(bin_id, j * bq, bq, axis=1)
        ewc = jax.lax.dynamic_slice_in_dim(ew, j * bq, bq, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, bq, n_ew), 2)
        onehot = (iota == bc[:, :, None]).astype(jnp.float32)
        bkt = jnp.sum(onehot * ewc[None, :, :].astype(jnp.float32),
                      axis=2).astype(jnp.int32)                  # (tile, bq)
        return jax.lax.dynamic_update_slice_in_dim(bucket, bkt, j * bq, 1)

    bucket = jax.lax.fori_loop(0, b // bq, map_chunk,
                               jnp.zeros((tile, b), jnp.int32))
    bucket = jnp.where(overflow, m, bucket)

    def hist_chunk(j, hist):
        bkt = jax.lax.dynamic_slice_in_dim(bucket, j * bq, bq, axis=1)
        wc = jax.lax.dynamic_slice_in_dim(w, j * bq, bq, axis=1)
        hiota = jax.lax.broadcasted_iota(jnp.int32, (tile, bq, hist_pad), 2)
        hoh = jnp.where(hiota == bkt[:, :, None], wc[:, :, None], 0)
        hc = jnp.sum(hoh, axis=0, dtype=jnp.int32)               # (bq, hist_pad)
        return jax.lax.dynamic_update_slice_in_dim(hist, hc, j * bq, 0)

    hist = jax.lax.fori_loop(0, b // bq, hist_chunk,
                             jnp.zeros((b, hist_pad), jnp.int32))
    return bucket, hist


def _fused_batch_kernel(codes_ref, vecs_ref, wmask_ref, luts_ref, qt_ref,
                        ew_ref, scal_ref, est_ref, bucket_ref, early_ref,
                        hist_ref, nmiss_ref, *, m: int, hist_pad: int,
                        mc: int, bq: int):
    codes = codes_ref[...].astype(jnp.int32)      # (TILE, M)
    vecs = vecs_ref[...]                          # (TILE, d)
    w = wmask_ref[...]                            # (TILE, B)
    luts = luts_ref[...]                          # (M*K, B)
    qt = qt_ref[...]                              # (d, B)
    ew = ew_ref[...]                              # (B, n_ew)
    s = scal_ref[...]                             # (B, 128)
    d_min, delta = s[:, 0], s[:, 1]
    tau_pred = s[:, 2].astype(jnp.int32)
    q_sq = s[:, 3]
    tile, m_sub = codes.shape
    b = w.shape[1]
    k_codes = luts.shape[0] // m_sub
    inf = jnp.float32(jnp.inf)

    # --- ADC estimates for all B queries: chunked one-hot MXU matmul ---
    def body(i, acc):
        cs = jax.lax.dynamic_slice_in_dim(codes, i * mc, mc, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(luts, i * mc * k_codes,
                                          mc * k_codes, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, mc, k_codes), 2)
        onehot = (iota == cs[:, :, None]).astype(jnp.float32)
        part = jax.lax.dot_general(
            onehot.reshape(tile, mc * k_codes), ls,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return acc + part                          # (tile, B)

    est2 = jax.lax.fori_loop(0, m_sub // mc, body,
                             jnp.zeros((tile, b), jnp.float32))
    est = jnp.sqrt(jnp.maximum(est2, 0.0))
    est = jnp.where(w > 0, est, inf)
    est_ref[...] = est

    # --- bucketize + per-query histogram ---
    bucket, tile_hist = bucketize_hist_tile(est, w, ew, d_min, delta, m,
                                            hist_pad, bq)
    bucket_ref[...] = bucket

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        nmiss_ref[...] = jnp.zeros_like(nmiss_ref)

    hist_ref[...] += tile_hist

    # --- early exact for all B queries: one MXU matmul on the hot tile ---
    xv = jax.lax.dot_general(vecs, qt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (tile, B)
    x_sq = jnp.sum(vecs * vecs, axis=1)
    exact = jnp.sqrt(jnp.maximum(
        x_sq[:, None] - 2.0 * xv + q_sq[None, :], 0.0))
    pred = (w > 0) & (bucket <= tau_pred[None, :])
    early_ref[...] = jnp.where(pred, exact, inf)

    # --- per-query miss counts (lanes left to the second gather pass) ---
    cnt = jnp.sum(((w > 0) & ~pred).astype(jnp.int32), axis=0)     # (B,)
    miota = jax.lax.broadcasted_iota(jnp.int32, (b, 128), 1)
    nmiss_ref[...] += jnp.where(miota == 0, cnt[:, None], 0)


def fused_scan_batch_pallas(
    codes: jax.Array,     # (n, M) int32/uint8, n % tile == 0, M % mc == 0
    vectors: jax.Array,   # (n, d) fp32 — shared candidate stream
    valid: jax.Array,     # (n, B) bool — per-query lane validity
    luts: jax.Array,      # (B, M, K) fp32 — one ADC table per query
    qs: jax.Array,        # (B, d) fp32
    d_min: jax.Array,     # (B,)
    delta: jax.Array,     # (B,)
    ew_maps: jax.Array,   # (B, n_ew) int32
    m: int,
    tau_pred: jax.Array,  # (B,) int32
    tile: int = TILE,
    mc: int = MC,
    bq: int = BQ,
    interpret: bool | None = None,
):
    """Batched fused scan: one pass over the shared candidate stream computes
    est/bucket/early for every query and accumulates a (B, m+1) histogram.

    The candidate gather happens ONCE per cluster tile (codes/vectors are the
    shared stream); all per-query work is MXU matmuls against the resident
    tile.  Returns (est (B, n), bucket (B, n), hist (B, m+1), early (B, n),
    nmiss (B,)).  Requires B % bq == 0 (wrappers pad the query batch).
    """
    interpret = resolve_interpret(interpret)
    n, m_sub = codes.shape
    d = vectors.shape[1]
    b = qs.shape[0]
    assert b % bq == 0, (b, bq)
    g = n // tile
    n_ew = ew_maps.shape[1]
    k_codes = luts.shape[2]
    hist_pad = ((m + 1 + 127) // 128) * 128
    scal = jnp.zeros((b, 128), jnp.float32)
    scal = scal.at[:, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[:, 1].set(delta.astype(jnp.float32))
    scal = scal.at[:, 2].set(tau_pred.astype(jnp.float32))
    scal = scal.at[:, 3].set(jnp.sum(qs * qs, axis=1))
    w = valid.astype(jnp.int32)                                  # (n, B)
    luts_t = luts.reshape(b, m_sub * k_codes).T                  # (M*K, B)
    qt = qs.T                                                    # (d, B)
    est, bucket, early, hist, nmiss = pl.pallas_call(
        functools.partial(_fused_batch_kernel, m=m, hist_pad=hist_pad,
                          mc=mc, bq=bq),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, m_sub), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((m_sub * k_codes, b), lambda i: (0, 0)),
            pl.BlockSpec((d, b), lambda i: (0, 0)),
            pl.BlockSpec((b, n_ew), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((b, hist_pad), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, b), jnp.float32),
            jax.ShapeDtypeStruct((n, b), jnp.int32),
            jax.ShapeDtypeStruct((n, b), jnp.float32),
            jax.ShapeDtypeStruct((b, hist_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, 128), jnp.int32),
        ],
        interpret=interpret,
    )(codes, vectors, w, luts_t, qt, ew_maps.astype(jnp.int32), scal)
    return est.T, bucket.T, hist[:, : m + 1], early.T, nmiss[:, 0]
