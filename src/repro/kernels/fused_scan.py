"""Pallas TPU kernel: fused estimate + bucketize + histogram + early exact.

This is the flagship kernel — the TPU-native realization of the paper's
Algorithm 4 (early re-ranking).  On CPU the paper co-locates PQ codes with the
fp32 vector and computes the exact distance "while the data is hot in cache".
On TPU the analogue is HBM-traffic fusion: one pass streams the code block AND
the vector block of a cluster tile through VMEM and produces

    est    — ADC estimate (one-hot matmul, see pq_adc.py),
    bucket — Eq. 6 bucket id (one-hot LUT),
    hist   — (m+1)-histogram accumulated across the grid (VMEM-resident),
    early  — exact ||q - x|| for lanes whose bucket <= tau_pred, else +inf,

eliminating the second gather pass over the re-rank pool (the cache-miss /
HBM-re-read saving of Table 2).  Exact distances are computed for all lanes
of the tile and masked — TPUs prefer redundant lanes over divergence; the
saving is memory traffic, not FLOPs.

VMEM working set at defaults (TILE=256, d<=1536, M<=384, K=16):
  vectors block 256*1536*4 = 1.5 MiB, codes 256*384*4 = 384 KiB,
  one-hot chunk 256*32*16*4 = 512 KiB, LUT + maps < 64 KiB  -> ~2.5 MiB,
comfortably inside ~16 MiB VMEM; m (Eq. 3') can stay in the hundreds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256
MC = 32


def _fused_kernel(codes_ref, vecs_ref, wmask_ref, lut_ref, qv_ref, ew_map_ref,
                  scal_ref, est_ref, bucket_ref, early_ref, hist_ref,
                  *, m: int, hist_pad: int, mc: int):
    codes = codes_ref[...].astype(jnp.int32)      # (TILE, M)
    vecs = vecs_ref[...]                          # (TILE, d)
    w = wmask_ref[...][0]                         # (TILE,)
    lut = lut_ref[...]                            # (M, K)
    qv = qv_ref[...]                              # (1, d)
    ew = ew_map_ref[...]                          # (1, n_ew)
    s = scal_ref[...]
    d_min, delta, q_sq = s[0, 0], s[0, 1], s[0, 3]
    tau_pred = s[0, 2].astype(jnp.int32)
    tile, m_sub = codes.shape
    k_codes = lut.shape[1]
    n_ew = ew.shape[1]
    inf = jnp.float32(jnp.inf)

    # --- ADC estimate (chunked one-hot matmul) ---
    def body(i, acc):
        cs = jax.lax.dynamic_slice_in_dim(codes, i * mc, mc, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(lut, i * mc, mc, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, mc, k_codes), 2)
        onehot = (iota == cs[:, :, None]).astype(ls.dtype)
        part = jax.lax.dot_general(
            onehot.reshape(tile, mc * k_codes), ls.reshape(mc * k_codes, 1),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return acc + part[:, 0]

    est2 = jax.lax.fori_loop(0, m_sub // mc, body,
                             jnp.zeros((tile,), jnp.float32))
    est = jnp.sqrt(jnp.maximum(est2, 0.0))
    est = jnp.where(w > 0, est, inf)
    est_ref[...] = est[None, :]

    # --- bucketize (Eq. 6, one-hot LUT) ---
    bin_f = jnp.floor((est - d_min) / delta)
    overflow = bin_f >= n_ew
    bin_id = jnp.clip(bin_f, 0, n_ew - 1).astype(jnp.int32)
    iota2 = jax.lax.broadcasted_iota(jnp.int32, (tile, n_ew), 1)
    onehot2 = (iota2 == bin_id[:, None]).astype(jnp.float32)
    bucket = jax.lax.dot_general(
        onehot2, ew.reshape(n_ew, 1).astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )[:, 0].astype(jnp.int32)
    bucket = jnp.where(overflow, m, bucket)
    bucket_ref[...] = bucket[None, :]

    # --- histogram accumulation (the only cross-tile state) ---
    hiota = jax.lax.broadcasted_iota(jnp.int32, (tile, hist_pad), 1)
    tile_hist = jnp.sum(
        jnp.where(hiota == bucket[:, None], w[:, None], 0), axis=0,
        dtype=jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += tile_hist[None, :]

    # --- early exact re-rank (Alg. 4): vectors are already in VMEM ---
    xv = jax.lax.dot_general(
        vecs, qv.reshape(-1, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    x_sq = jnp.sum(vecs * vecs, axis=1)
    exact = jnp.sqrt(jnp.maximum(x_sq - 2.0 * xv + q_sq, 0.0))
    pred = (w > 0) & (bucket <= tau_pred)
    early_ref[...] = jnp.where(pred, exact, inf)[None, :]


def fused_scan_pallas(
    codes: jax.Array,     # (n, M) int32/uint8, n % tile == 0, M % mc == 0
    vectors: jax.Array,   # (n, d) fp32
    valid: jax.Array,     # (n,) bool
    lut: jax.Array,       # (M, K) fp32
    q: jax.Array,         # (d,) fp32
    d_min: jax.Array,
    delta: jax.Array,
    ew_map: jax.Array,    # (n_ew,) int32
    m: int,
    tau_pred: jax.Array,  # scalar int32
    tile: int = TILE,
    mc: int = MC,
    interpret: bool = True,
):
    """Returns (est (n,), bucket (n,), hist (m+1,), early (n,))."""
    n, m_sub = codes.shape
    d = vectors.shape[1]
    g = n // tile
    n_ew = ew_map.shape[0]
    hist_pad = ((m + 1 + 127) // 128) * 128
    scal = jnp.zeros((1, 128), jnp.float32)
    scal = scal.at[0, 0].set(d_min.astype(jnp.float32))
    scal = scal.at[0, 1].set(delta.astype(jnp.float32))
    scal = scal.at[0, 2].set(tau_pred.astype(jnp.float32))
    scal = scal.at[0, 3].set(jnp.sum(q * q))
    w = valid.astype(jnp.int32)
    est, bucket, early, hist = pl.pallas_call(
        functools.partial(_fused_kernel, m=m, hist_pad=hist_pad, mc=mc),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, m_sub), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, n_ew), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, hist_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, tile), jnp.float32),
            jax.ShapeDtypeStruct((g, tile), jnp.int32),
            jax.ShapeDtypeStruct((g, tile), jnp.float32),
            jax.ShapeDtypeStruct((1, hist_pad), jnp.int32),
        ],
        interpret=interpret,
    )(codes, vectors, w.reshape(1, n), lut, q.reshape(1, d),
      ew_map.reshape(1, n_ew), scal)
    return est.reshape(n), bucket.reshape(n), hist[0, : m + 1], early.reshape(n)
