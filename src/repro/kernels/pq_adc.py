"""Pallas TPU kernel: PQ asymmetric distance computation (FastScan analogue).

CPU FastScan uses AVX shuffles to look 16-entry LUTs up for 16 codes at once.
The MXU analogue recasts the lookup as a one-hot matmul:

    est[n] = sum_m LUT[m, code[n, m]]
           = reshape(onehot(codes), (TILE, M*K)) @ reshape(LUT, (M*K, 1))

The one-hot tensor is built in VMEM in M-chunks of ``mc`` sub-quantizers so the
working set stays bounded: (TILE, mc, K) fp32 = 256*32*16*4 = 512 KiB per
chunk at the default tile, well inside VMEM alongside the code block.

Tiling: grid over row tiles of ``TILE`` codes; LUT replicated to every step
(index_map -> (0, 0)); code block (TILE, M) streams HBM->VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret

TILE = 256
MC = 32  # sub-quantizer chunk


def _adc_kernel(codes_ref, lut_ref, out_ref, *, mc: int):
    codes = codes_ref[...].astype(jnp.int32)         # (TILE, M)
    lut = lut_ref[...]                               # (M, K)
    tile, m_sub = codes.shape
    k_codes = lut.shape[1]
    n_chunks = m_sub // mc

    def body(i, acc):
        cs = jax.lax.dynamic_slice_in_dim(codes, i * mc, mc, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(lut, i * mc, mc, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, mc, k_codes), 2)
        onehot = (iota == cs[:, :, None]).astype(ls.dtype)
        part = jax.lax.dot_general(
            onehot.reshape(tile, mc * k_codes),
            ls.reshape(mc * k_codes, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc + part[:, 0]

    acc = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((tile,), lut_ref.dtype))
    out_ref[...] = acc[None, :]


def adc_pallas(codes: jax.Array, lut: jax.Array, *, tile: int = TILE,
               mc: int = MC, interpret: bool | None = None) -> jax.Array:
    """(n, M) codes + (M, K) LUT -> (n,) squared-distance estimates.

    Caller guarantees n % tile == 0 and M % mc == 0 (ops.py pads).
    """
    interpret = resolve_interpret(interpret)
    n, m_sub = codes.shape
    grid = (n // tile,)
    out = pl.pallas_call(
        functools.partial(_adc_kernel, mc=mc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, m_sub), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // tile, tile), lut.dtype),
        interpret=interpret,
    )(codes, lut)
    return out.reshape(n)


# --------------------------------------------------------------------------
# Batched (multi-query) ADC
# --------------------------------------------------------------------------

def _adc_batch_kernel(codes_ref, luts_ref, out_ref, *, mc: int):
    codes = codes_ref[...].astype(jnp.int32)         # (TILE, M)
    luts = luts_ref[...]                             # (M*K, B)
    tile, m_sub = codes.shape
    b = luts.shape[1]
    k_codes = luts.shape[0] // m_sub

    def body(i, acc):
        cs = jax.lax.dynamic_slice_in_dim(codes, i * mc, mc, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(luts, i * mc * k_codes,
                                          mc * k_codes, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, mc, k_codes), 2)
        onehot = (iota == cs[:, :, None]).astype(jnp.float32)
        part = jax.lax.dot_general(
            onehot.reshape(tile, mc * k_codes), ls,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return acc + part                            # (TILE, B)

    acc = jax.lax.fori_loop(0, m_sub // mc, body,
                            jnp.zeros((tile, b), jnp.float32))
    out_ref[...] = acc


def adc_batch_pallas(codes: jax.Array, luts: jax.Array, *, tile: int = TILE,
                     mc: int = MC,
                     interpret: bool | None = None) -> jax.Array:
    """Shared (n, M) codes x per-query (B, M, K) LUTs -> (B, n) squared
    estimates: one code-block stream, ADC for all B queries as a single MXU
    matmul per chunk.

    Caller guarantees n % tile == 0 and M % mc == 0 (ops.py pads).
    """
    interpret = resolve_interpret(interpret)
    n, m_sub = codes.shape
    b, _, k_codes = luts.shape
    luts_t = luts.reshape(b, m_sub * k_codes).T      # (M*K, B)
    out = pl.pallas_call(
        functools.partial(_adc_batch_kernel, mc=mc),
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, m_sub), lambda i: (i, 0)),
            pl.BlockSpec((m_sub * k_codes, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(codes, luts_t)
    return out.T
