"""Pallas TPU kernel: PQ asymmetric distance computation (FastScan analogue).

CPU FastScan uses AVX shuffles to look 16-entry LUTs up for 16 codes at once.
The MXU analogue recasts the lookup as a one-hot matmul:

    est[n] = sum_m LUT[m, code[n, m]]
           = reshape(onehot(codes), (TILE, M*K)) @ reshape(LUT, (M*K, 1))

The one-hot tensor is built in VMEM in M-chunks of ``mc`` sub-quantizers so the
working set stays bounded: (TILE, mc, K) fp32 = 256*32*16*4 = 512 KiB per
chunk at the default tile, well inside VMEM alongside the code block.

Tiling: grid over row tiles of ``TILE`` codes; LUT replicated to every step
(index_map -> (0, 0)); code block (TILE, M) streams HBM->VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256
MC = 32  # sub-quantizer chunk


def _adc_kernel(codes_ref, lut_ref, out_ref, *, mc: int):
    codes = codes_ref[...].astype(jnp.int32)         # (TILE, M)
    lut = lut_ref[...]                               # (M, K)
    tile, m_sub = codes.shape
    k_codes = lut.shape[1]
    n_chunks = m_sub // mc

    def body(i, acc):
        cs = jax.lax.dynamic_slice_in_dim(codes, i * mc, mc, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(lut, i * mc, mc, axis=0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile, mc, k_codes), 2)
        onehot = (iota == cs[:, :, None]).astype(ls.dtype)
        part = jax.lax.dot_general(
            onehot.reshape(tile, mc * k_codes),
            ls.reshape(mc * k_codes, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc + part[:, 0]

    acc = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((tile,), lut_ref.dtype))
    out_ref[...] = acc[None, :]


def adc_pallas(codes: jax.Array, lut: jax.Array, *, tile: int = TILE,
               mc: int = MC, interpret: bool = True) -> jax.Array:
    """(n, M) codes + (M, K) LUT -> (n,) squared-distance estimates.

    Caller guarantees n % tile == 0 and M % mc == 0 (ops.py pads).
    """
    n, m_sub = codes.shape
    grid = (n // tile,)
    out = pl.pallas_call(
        functools.partial(_adc_kernel, mc=mc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, m_sub), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // tile, tile), lut.dtype),
        interpret=interpret,
    )(codes, lut)
    return out.reshape(n)
