"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile/lane multiples, dtype plumbing, and the
interpret-mode switch (CPU containers execute the kernel bodies in Python via
``interpret=True``; on TPU the same calls compile to Mosaic).

The ``*_batch`` wrappers additionally route between two backends:

  * ``"pallas"`` — the batched Pallas kernels (Mosaic on TPU; the interpret
    emulator elsewhere).  The emulator is a correctness tool, ~100x slower
    than XLA on CPU, so it is never the default off-TPU.
  * ``"ref"``    — the pure-jnp mirrors in kernels/ref.py: the same batched
    math (shared candidate stream, batched matmuls) compiled by XLA.  This is
    the production CPU fallback.

``backend=None`` selects pallas on TPU and ref elsewhere, so the batched
search engine runs the fused kernels wherever they pay off and stays fast on
CPU containers/CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bucket_hist as _bh
from repro.kernels import fused_scan as _fs
from repro.kernels import l2_rerank as _l2
from repro.kernels import pq_adc as _adc
from repro.kernels import rabitq_est as _rq
from repro.kernels import rabitq_fused as _rqf
from repro.kernels import ref as _ref
from repro.kernels import shard_collect as _sc
from repro.kernels.platform import default_interpret, on_tpu

INF = jnp.inf


def _interpret() -> bool:
    return default_interpret()


def resolve_backend(backend: str | None) -> str:
    if backend is None:
        return "pallas" if on_tpu() else "ref"
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown kernel backend: {backend!r}")
    return backend


def _pad_rows(x: jax.Array, mult: int, fill) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=fill)


def _pad_cols(x: jax.Array, mult: int, fill) -> jax.Array:
    c = x.shape[1]
    pad = (-c) % mult
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, width, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("tile", "mc"))
def pq_adc(codes: jax.Array, lut: jax.Array, tile: int = _adc.TILE,
           mc: int = _adc.MC) -> jax.Array:
    """(n, M) codes, (M, K) LUT -> (n,) squared-distance estimates."""
    n = codes.shape[0]
    codes_p = _pad_cols(_pad_rows(codes.astype(jnp.int32), tile, 0), mc, 0)
    lut_p = jnp.pad(lut, ((0, codes_p.shape[1] - lut.shape[0]), (0, 0)))
    out = _adc.adc_pallas(codes_p, lut_p, tile=tile, mc=mc,
                          interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("eps0", "tile"))
def rabitq_est(codes: jax.Array, norm_o: jax.Array, f_o: jax.Array,
               v: jax.Array, norm_q: jax.Array, eps0: float = 3.0,
               tile: int = _rq.TILE):
    """±1 codes (n, d) -> (est, lb, ub), matching kernels.ref.rabitq_est."""
    n, d = codes.shape
    codes_p = _pad_cols(_pad_rows(codes, tile, 0), 128, 0)
    v_p = jnp.pad(v, (0, codes_p.shape[1] - d))
    norm_p = _pad_rows(norm_o, tile, 0.0)
    f_p = _pad_rows(f_o, tile, 1.0)
    est, lb, ub = _rq.rabitq_est_pallas(
        codes_p, norm_p, f_p, v_p, norm_q, d_logical=d, eps0=eps0,
        tile=tile, interpret=_interpret())
    return est[:n], lb[:n], ub[:n]


@functools.partial(jax.jit, static_argnames=("m", "tile"))
def bucket_hist(dists: jax.Array, valid: jax.Array, d_min: jax.Array,
                delta: jax.Array, ew_map: jax.Array, m: int,
                tile: int = _bh.TILE):
    """(n,) distances -> (bucket_ids (n,), hist (m+1,))."""
    n = dists.shape[0]
    d_p = _pad_rows(dists, tile, jnp.inf)
    v_p = _pad_rows(valid, tile, False)
    bucket, hist = _bh.bucket_hist_pallas(
        d_p, v_p, d_min, delta, ew_map.astype(jnp.int32), m, tile=tile,
        interpret=_interpret())
    return bucket[:n], hist


@functools.partial(jax.jit, static_argnames=("m", "tile", "mc"))
def fused_scan(codes: jax.Array, vectors: jax.Array, valid: jax.Array,
               lut: jax.Array, q: jax.Array, d_min: jax.Array,
               delta: jax.Array, ew_map: jax.Array, m: int,
               tau_pred: jax.Array, tile: int = _fs.TILE, mc: int = _fs.MC):
    """Fused estimate+bucketize+hist+early-exact over a candidate block.

    Returns (est (n,), bucket (n,), hist (m+1,), early (n,), nmiss ())."""
    n, d = vectors.shape
    codes_p = _pad_cols(_pad_rows(codes.astype(jnp.int32), tile, 0), mc, 0)
    lut_p = jnp.pad(lut, ((0, codes_p.shape[1] - lut.shape[0]), (0, 0)))
    vecs_p = _pad_cols(_pad_rows(vectors, tile, 0.0), 128, 0.0)
    q_p = jnp.pad(q, (0, vecs_p.shape[1] - d))
    valid_p = _pad_rows(valid, tile, False)
    est, bucket, hist, early, nmiss = _fs.fused_scan_pallas(
        codes_p, vecs_p, valid_p, lut_p, q_p, d_min, delta,
        ew_map.astype(jnp.int32), m, tau_pred, tile=tile, mc=mc,
        interpret=_interpret())
    return est[:n], bucket[:n], hist, early[:n], nmiss


@functools.partial(jax.jit, static_argnames=("tile",))
def l2_exact(x: jax.Array, q: jax.Array, tile: int = _l2.TILE) -> jax.Array:
    n, d = x.shape
    x_p = _pad_cols(_pad_rows(x, tile, 0.0), 128, 0.0)
    q_p = jnp.pad(q, (0, x_p.shape[1] - d))
    return _l2.l2_pallas(x_p, q_p, tile=tile, interpret=_interpret())[:n]


# --------------------------------------------------------------------------
# Batched (multi-query) wrappers
# --------------------------------------------------------------------------

def _pad_batch(b: int, bq: int) -> int:
    return (-b) % bq


@functools.partial(jax.jit, static_argnames=("tile", "mc", "backend"))
def pq_adc_batch(codes: jax.Array, luts: jax.Array, tile: int = _adc.TILE,
                 mc: int = _adc.MC, backend: str | None = None) -> jax.Array:
    """Shared (n, M) codes x per-query (B, M, K) LUTs -> (B, n) squared
    estimates."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return _ref.pq_adc_batch(codes, luts)
    n = codes.shape[0]
    codes_p = _pad_cols(_pad_rows(codes.astype(jnp.int32), tile, 0), mc, 0)
    m_pad = codes_p.shape[1] - luts.shape[1]
    luts_p = jnp.pad(luts, ((0, 0), (0, m_pad), (0, 0)))
    out = _adc.adc_batch_pallas(codes_p, luts_p, tile=tile, mc=mc,
                                interpret=_interpret())
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("m", "tile", "mc", "backend"))
def fused_scan_batch(codes: jax.Array, vectors: jax.Array, valid: jax.Array,
                     luts: jax.Array, qs: jax.Array, d_min: jax.Array,
                     delta: jax.Array, ew_maps: jax.Array, m: int,
                     tau_pred: jax.Array, tile: int = _fs.TILE,
                     mc: int = _fs.MC, backend: str | None = None):
    """Batched fused estimate+bucketize+hist+early-exact over a shared
    candidate stream.

    ``codes`` (n, M) / ``vectors`` (n, d) are the stream shared by every
    query; ``valid`` (B, n) masks each query's probed lanes; ``luts``
    (B, M, K), ``qs`` (B, d), codebook params and ``tau_pred`` are per-query.
    Returns (est (B, n), bucket (B, n), hist (B, m+1), early (B, n),
    nmiss (B,)) — nmiss counts valid lanes with bucket > tau_pred, the lanes
    the predictive early-exact pass leaves to the second gather.
    """
    backend = resolve_backend(backend)
    if backend == "ref":
        return _ref.fused_scan_batch(codes, vectors, valid, luts, qs, d_min,
                                     delta, ew_maps, m, tau_pred)
    n, d = vectors.shape
    b = qs.shape[0]
    bp = _pad_batch(b, _fs.BQ)
    codes_p = _pad_cols(_pad_rows(codes.astype(jnp.int32), tile, 0), mc, 0)
    m_pad = codes_p.shape[1] - luts.shape[1]
    luts_p = jnp.pad(luts, ((0, bp), (0, m_pad), (0, 0)))
    vecs_p = _pad_cols(_pad_rows(vectors, tile, 0.0), 128, 0.0)
    qs_p = jnp.pad(qs, ((0, bp), (0, vecs_p.shape[1] - d)))
    valid_p = jnp.pad(_pad_cols(valid, tile, False), ((0, bp), (0, 0)))
    d_min_p = jnp.pad(d_min, (0, bp))
    delta_p = jnp.pad(delta, (0, bp), constant_values=1.0)
    ew_p = jnp.pad(ew_maps.astype(jnp.int32), ((0, bp), (0, 0)))
    tau_p = jnp.pad(tau_pred.astype(jnp.int32), (0, bp), constant_values=-1)
    est, bucket, hist, early, nmiss = _fs.fused_scan_batch_pallas(
        codes_p, vecs_p, valid_p.T, luts_p, qs_p, d_min_p, delta_p, ew_p, m,
        tau_p, tile=tile, mc=mc, interpret=_interpret())
    return est[:b, :n], bucket[:b, :n], hist[:b], early[:b, :n], nmiss[:b]


@functools.partial(jax.jit, static_argnames=("m", "eps0", "tile", "backend"))
def fused_rabitq_scan_batch(codes: jax.Array, vectors: jax.Array,
                            norm_o: jax.Array, f_o: jax.Array,
                            cl: jax.Array, centroids: jax.Array,
                            rot: jax.Array, qs: jax.Array, d2: jax.Array,
                            valid: jax.Array, d_min: jax.Array,
                            delta: jax.Array, ew_maps: jax.Array, m: int,
                            tau_inline: jax.Array, eps0: float = 3.0,
                            tile: int = _rqf.TILE,
                            backend: str | None = None):
    """Batched bound-fused RaBitQ scan over a shared candidate stream.

    ``codes``/``vectors``/``norm_o``/``f_o``/``cl`` are the stream shared by
    every query (``cl`` maps each lane to its clamped owning cluster);
    ``qs``, the (B, C) squared routing distances ``d2``, the per-query
    codebook params and ``tau_inline`` are per-query.  Returns
    ``(est, lb, ub, bucket_lb, bucket_ub, hist_lb, hist_ub, exact,
    certified, nmiss)`` — see ``kernels.ref.fused_rabitq_scan_batch`` for
    the contract; ``exact`` is finite exactly on certified lanes (the
    bound-certified inline band the second gather pass can skip).
    """
    backend = resolve_backend(backend)
    tau_inline = tau_inline.astype(jnp.int32)
    if backend == "ref":
        return _ref.fused_rabitq_scan_batch(
            codes.astype(jnp.float32), vectors, norm_o, f_o, cl, centroids,
            rot, qs, d2, valid, d_min, delta, ew_maps, m, tau_inline, eps0)
    n, d = vectors.shape
    b = qs.shape[0]
    bp = _pad_batch(b, _rqf.BQ)
    codes_f = codes.astype(jnp.float32)
    # query-independent decomposition inputs (see ref.rabitq_bounds_stream)
    h = centroids @ rot.T
    s2 = jnp.sum(codes_f * h[cl], axis=1)
    g = qs @ rot.T
    nq_lane = jnp.sqrt(d2)[:, cl]                              # (B, n)
    codes_p = _pad_cols(_pad_rows(codes_f, tile, 0.0), 128, 0.0)
    vecs_p = _pad_cols(_pad_rows(vectors, tile, 0.0), 128, 0.0)
    dp = vecs_p.shape[1] - d
    s2_p = _pad_rows(s2, tile, 0.0)
    norm_p = _pad_rows(norm_o, tile, 0.0)
    f_p = _pad_rows(f_o, tile, 1.0)
    valid_p = jnp.pad(_pad_cols(valid, tile, False), ((0, bp), (0, 0)))
    nq_p = jnp.pad(_pad_cols(nq_lane, tile, 1.0), ((0, bp), (0, 0)),
                   constant_values=1.0)
    g_p = jnp.pad(g, ((0, bp), (0, dp)))
    qs_p = jnp.pad(qs, ((0, bp), (0, dp)))
    d_min_p = jnp.pad(d_min, (0, bp))
    delta_p = jnp.pad(delta, (0, bp), constant_values=1.0)
    ew_p = jnp.pad(ew_maps.astype(jnp.int32), ((0, bp), (0, 0)))
    tau_p = jnp.pad(tau_inline, (0, bp), constant_values=-1)
    outs = _rqf.fused_rabitq_scan_batch_pallas(
        codes_p, vecs_p, s2_p, norm_p, f_p, valid_p.T, nq_p.T, g_p, qs_p,
        d_min_p, delta_p, ew_p, m, tau_p, d_logical=d, eps0=eps0, tile=tile,
        interpret=_interpret())
    (est, lb, ub, blb, bub, hist_lb, hist_ub, exact, cert, nmiss) = outs
    return (est[:b, :n], lb[:b, :n], ub[:b, :n], blb[:b, :n], bub[:b, :n],
            hist_lb[:b], hist_ub[:b], exact[:b, :n], cert[:b, :n],
            nmiss[:b])


@functools.partial(jax.jit, static_argnames=("m", "eps0", "tile", "backend"))
def fused_rabitq_scan(codes: jax.Array, vectors: jax.Array,
                      norm_o: jax.Array, f_o: jax.Array, cl: jax.Array,
                      centroids: jax.Array, rot: jax.Array, q: jax.Array,
                      d2: jax.Array, valid: jax.Array, d_min: jax.Array,
                      delta: jax.Array, ew_map: jax.Array, m: int,
                      tau_inline: jax.Array, eps0: float = 3.0,
                      tile: int = _rqf.TILE, backend: str | None = None):
    """Single-query bound-fused RaBitQ scan: the batched kernel on a
    singleton batch (the batched formulation is the native one — a single
    query is just B == 1)."""
    outs = fused_rabitq_scan_batch(
        codes, vectors, norm_o, f_o, cl, centroids, rot, q[None], d2[None],
        valid[None], jnp.asarray(d_min)[None], jnp.asarray(delta)[None],
        ew_map[None], m, jnp.asarray(tau_inline, jnp.int32)[None],
        eps0=eps0, tile=tile, backend=backend)
    return tuple(o[0] for o in outs)


@functools.partial(jax.jit, static_argnames=("m", "tile", "backend"))
def bucket_hist_batch(dists: jax.Array, valid: jax.Array, d_min: jax.Array,
                      delta: jax.Array, ew_maps: jax.Array, m: int,
                      tile: int = _bh.TILE, backend: str | None = None):
    """(B, n) distances, per-query codebooks -> (bucket (B, n), hist
    (B, m+1))."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return _ref.bucket_hist_batch(dists, valid, d_min, delta,
                                      ew_maps.astype(jnp.int32), m)
    b, n = dists.shape
    bp = _pad_batch(b, _bh.BQ)
    d_p = jnp.pad(_pad_cols(dists, tile, jnp.inf), ((0, bp), (0, 0)),
                  constant_values=jnp.inf)
    v_p = jnp.pad(_pad_cols(valid, tile, False), ((0, bp), (0, 0)))
    d_min_p = jnp.pad(d_min, (0, bp))
    delta_p = jnp.pad(delta, (0, bp), constant_values=1.0)
    ew_p = jnp.pad(ew_maps.astype(jnp.int32), ((0, bp), (0, 0)))
    bucket, hist = _bh.bucket_hist_batch_pallas(
        d_p, v_p, d_min_p, delta_p, ew_p, m, tile=tile,
        interpret=_interpret())
    return bucket[:b, :n], hist[:b]


@functools.partial(jax.jit,
                   static_argnames=("m", "budget", "tile", "backend"))
def shard_collect_batch(dists: jax.Array, valid: jax.Array,
                        d_min: jax.Array, delta: jax.Array,
                        ew_maps: jax.Array, m: int, tau_spec: jax.Array,
                        budget: int, tile: int = _sc.TILE,
                        backend: str | None = None):
    """Fused shard collect: (B, n) distances -> (bucket (B, n), hist
    (B, m+1), spec_pos (B, budget), spec_ok (B, budget), spec_count (B,)).

    One stream pass computes the bucket ids and histogram AND speculatively
    compacts the lanes at or below the provisional ``tau_spec`` into the
    fixed ``budget`` position buffer, in stream order (``tau_spec = -1``
    compacts nothing).  ``spec_count`` is the total matching-lane count —
    above ``budget`` signals overflow.  Feed the buffer to
    ``core.distributed.bbc_survivors_batch(spec=...)``.
    """
    backend = resolve_backend(backend)
    tau_spec = tau_spec.astype(jnp.int32)
    if backend == "ref":
        return _ref.shard_collect_batch(
            dists, valid, d_min, delta, ew_maps.astype(jnp.int32), m,
            tau_spec, budget)
    b, n = dists.shape
    bp = _pad_batch(b, _sc.BQ)
    d_p = jnp.pad(_pad_cols(dists, tile, jnp.inf), ((0, bp), (0, 0)),
                  constant_values=jnp.inf)
    v_p = jnp.pad(_pad_cols(valid, tile, False), ((0, bp), (0, 0)))
    d_min_p = jnp.pad(d_min, (0, bp))
    delta_p = jnp.pad(delta, (0, bp), constant_values=1.0)
    ew_p = jnp.pad(ew_maps.astype(jnp.int32), ((0, bp), (0, 0)))
    tau_p = jnp.pad(tau_spec, (0, bp), constant_values=-1)
    bucket, hist, pos, cnt = _sc.shard_collect_batch_pallas(
        d_p, v_p, d_min_p, delta_p, ew_p, m, tau_p, budget, tile=tile,
        interpret=_interpret())
    pos = pos[:b]
    ok = pos < n                  # padded-lane sentinel (n_pad) -> invalid
    return (bucket[:b, :n], hist[:b], jnp.where(ok, pos, n), ok, cnt[:b])


@functools.partial(jax.jit, static_argnames=("budget", "tile", "backend"))
def spec_compact_batch(bucket: jax.Array, valid: jax.Array,
                       tau_spec: jax.Array, budget: int,
                       tile: int = _sc.TILE, backend: str | None = None):
    """Compaction-only form of ``shard_collect_batch`` for scans whose
    bucket ids already exist (the bound-fused RaBitQ kernel emits
    bucket_lb itself).  Returns (spec_pos, spec_ok, spec_count)."""
    backend = resolve_backend(backend)
    tau_spec = tau_spec.astype(jnp.int32)
    if backend == "ref":
        return _ref.spec_compact_batch(bucket, valid, tau_spec, budget)
    b, n = bucket.shape
    b_p = _pad_cols(bucket.astype(jnp.int32), tile, 0)
    v_p = _pad_cols(valid, tile, False)
    pos, cnt = _sc.spec_compact_batch_pallas(
        b_p, v_p, tau_spec, budget, tile=tile, interpret=_interpret())
    ok = pos < n
    return jnp.where(ok, pos, n), ok, cnt


@functools.partial(jax.jit, static_argnames=("tile", "backend"))
def l2_exact_batch(x: jax.Array, qs: jax.Array, tile: int = _l2.TILE,
                   backend: str | None = None) -> jax.Array:
    """(n, d) shared vectors x (B, d) queries -> (B, n) exact distances."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return _ref.l2_exact_batch(x, qs)
    n, d = x.shape
    x_p = _pad_cols(_pad_rows(x, tile, 0.0), 128, 0.0)
    qs_p = jnp.pad(qs, ((0, 0), (0, x_p.shape[1] - d)))
    return _l2.l2_batch_pallas(x_p, qs_p, tile=tile,
                               interpret=_interpret())[:, :n]
