"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile/lane multiples, dtype plumbing, and the
interpret-mode switch (CPU containers execute the kernel bodies in Python via
``interpret=True``; on TPU the same calls compile to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bucket_hist as _bh
from repro.kernels import fused_scan as _fs
from repro.kernels import l2_rerank as _l2
from repro.kernels import pq_adc as _adc
from repro.kernels import rabitq_est as _rq

INF = jnp.inf


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pad_rows(x: jax.Array, mult: int, fill) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=fill)


def _pad_cols(x: jax.Array, mult: int, fill) -> jax.Array:
    c = x.shape[1]
    pad = (-c) % mult
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, width, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("tile", "mc"))
def pq_adc(codes: jax.Array, lut: jax.Array, tile: int = _adc.TILE,
           mc: int = _adc.MC) -> jax.Array:
    """(n, M) codes, (M, K) LUT -> (n,) squared-distance estimates."""
    n = codes.shape[0]
    codes_p = _pad_cols(_pad_rows(codes.astype(jnp.int32), tile, 0), mc, 0)
    lut_p = jnp.pad(lut, ((0, codes_p.shape[1] - lut.shape[0]), (0, 0)))
    out = _adc.adc_pallas(codes_p, lut_p, tile=tile, mc=mc,
                          interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("eps0", "tile"))
def rabitq_est(codes: jax.Array, norm_o: jax.Array, f_o: jax.Array,
               v: jax.Array, norm_q: jax.Array, eps0: float = 3.0,
               tile: int = _rq.TILE):
    """±1 codes (n, d) -> (est, lb, ub), matching kernels.ref.rabitq_est."""
    n, d = codes.shape
    codes_p = _pad_cols(_pad_rows(codes, tile, 0), 128, 0)
    v_p = jnp.pad(v, (0, codes_p.shape[1] - d))
    norm_p = _pad_rows(norm_o, tile, 0.0)
    f_p = _pad_rows(f_o, tile, 1.0)
    est, lb, ub = _rq.rabitq_est_pallas(
        codes_p, norm_p, f_p, v_p, norm_q, d_logical=d, eps0=eps0,
        tile=tile, interpret=_interpret())
    return est[:n], lb[:n], ub[:n]


@functools.partial(jax.jit, static_argnames=("m", "tile"))
def bucket_hist(dists: jax.Array, valid: jax.Array, d_min: jax.Array,
                delta: jax.Array, ew_map: jax.Array, m: int,
                tile: int = _bh.TILE):
    """(n,) distances -> (bucket_ids (n,), hist (m+1,))."""
    n = dists.shape[0]
    d_p = _pad_rows(dists, tile, jnp.inf)
    v_p = _pad_rows(valid, tile, False)
    bucket, hist = _bh.bucket_hist_pallas(
        d_p, v_p, d_min, delta, ew_map.astype(jnp.int32), m, tile=tile,
        interpret=_interpret())
    return bucket[:n], hist


@functools.partial(jax.jit, static_argnames=("m", "tile", "mc"))
def fused_scan(codes: jax.Array, vectors: jax.Array, valid: jax.Array,
               lut: jax.Array, q: jax.Array, d_min: jax.Array,
               delta: jax.Array, ew_map: jax.Array, m: int,
               tau_pred: jax.Array, tile: int = _fs.TILE, mc: int = _fs.MC):
    """Fused estimate+bucketize+hist+early-exact over a candidate block."""
    n, d = vectors.shape
    codes_p = _pad_cols(_pad_rows(codes.astype(jnp.int32), tile, 0), mc, 0)
    lut_p = jnp.pad(lut, ((0, codes_p.shape[1] - lut.shape[0]), (0, 0)))
    vecs_p = _pad_cols(_pad_rows(vectors, tile, 0.0), 128, 0.0)
    q_p = jnp.pad(q, (0, vecs_p.shape[1] - d))
    valid_p = _pad_rows(valid, tile, False)
    est, bucket, hist, early = _fs.fused_scan_pallas(
        codes_p, vecs_p, valid_p, lut_p, q_p, d_min, delta,
        ew_map.astype(jnp.int32), m, tau_pred, tile=tile, mc=mc,
        interpret=_interpret())
    return est[:n], bucket[:n], hist, early[:n]


@functools.partial(jax.jit, static_argnames=("tile",))
def l2_exact(x: jax.Array, q: jax.Array, tile: int = _l2.TILE) -> jax.Array:
    n, d = x.shape
    x_p = _pad_cols(_pad_rows(x, tile, 0.0), 128, 0.0)
    q_p = jnp.pad(q, (0, x_p.shape[1] - d))
    return _l2.l2_pallas(x_p, q_p, tile=tile, interpret=_interpret())[:n]
