"""Pallas TPU kernel: RaBitQ bounded estimator.

CPU RaBitQ computes bitwise dot products via popcount; the MXU analogue is a
(TILE, d) x (d, 1) matmul of the ±1 int8 code block against the rotated unit
query residual.  Per-object factors (norm_o, f_o) stream alongside; scalars
(norm_q, eps0, 1/sqrt(d), d-1) arrive packed in a (1, 128) fp32 lane so the
kernel has no SMEM dependencies (portable to interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret

TILE = 256


def _rq_kernel(codes_ref, norm_ref, f_ref, v_ref, scal_ref,
               est_ref, lb_ref, ub_ref):
    codes = codes_ref[...].astype(jnp.float32)      # (TILE, d)
    v = v_ref[...]                                   # (1, d)
    no = norm_ref[...][0]                            # (TILE,)
    fo = f_ref[...][0]                               # (TILE,)
    s = scal_ref[...]                                # (1, 128)
    nq, eps0, inv_sqrt_d, dm1 = s[0, 0], s[0, 1], s[0, 2], s[0, 3]

    xv = jax.lax.dot_general(
        codes, v.reshape(-1, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0] * inv_sqrt_d
    ip = xv / fo
    err = eps0 * jnp.sqrt((1.0 - fo * fo) / (fo * fo * dm1))
    scale = 2.0 * nq * no
    base = nq * nq + no * no
    zero = jnp.zeros_like(base)
    est_ref[...] = jnp.sqrt(jnp.maximum(base - scale * ip, zero))[None, :]
    lb_ref[...] = jnp.sqrt(jnp.maximum(base - scale * (ip + err), zero))[None, :]
    ub_ref[...] = jnp.sqrt(jnp.maximum(base - scale * (ip - err), zero))[None, :]


def rabitq_est_pallas(
    codes: jax.Array,    # (n, d) int8, n % tile == 0, d lane-padded with 0s
    norm_o: jax.Array,   # (n,)
    f_o: jax.Array,      # (n,)
    v: jax.Array,        # (d,)
    norm_q: jax.Array,   # scalar
    d_logical: int,      # true dimensionality (before lane padding)
    eps0: float = 3.0,
    tile: int = TILE,
    interpret: bool | None = None,
):
    interpret = resolve_interpret(interpret)
    n, d = codes.shape
    g = n // tile
    scal = jnp.zeros((1, 128), jnp.float32)
    scal = scal.at[0, 0].set(norm_q.astype(jnp.float32))
    scal = scal.at[0, 1].set(eps0)
    scal = scal.at[0, 2].set(1.0 / jnp.sqrt(jnp.float32(d_logical)))
    scal = scal.at[0, 3].set(jnp.float32(d_logical - 1))
    out_sds = jax.ShapeDtypeStruct((g, tile), jnp.float32)
    est, lb, ub = pl.pallas_call(
        _rq_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_shape=[out_sds, out_sds, out_sds],
        interpret=interpret,
    )(codes, norm_o.reshape(1, n), f_o.reshape(1, n), v.reshape(1, d), scal)
    return est.reshape(n), lb.reshape(n), ub.reshape(n)
