"""Pallas TPU kernel: tiled exact ||q - x|| for the re-rank pool.

Straight MXU matvec per tile with the norm identity — the exact-distance
hot spot of every re-rank phase.  Included so the whole search inner loop
(estimate -> bucketize -> select -> re-rank) runs on Pallas kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret

TILE = 256


def _l2_kernel(x_ref, q_ref, scal_ref, out_ref):
    x = x_ref[...]                     # (TILE, d)
    q = q_ref[...]                     # (1, d)
    q_sq = scal_ref[...][0, 0]
    xv = jax.lax.dot_general(
        x, q.reshape(-1, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    x_sq = jnp.sum(x * x, axis=1)
    out_ref[...] = jnp.sqrt(jnp.maximum(x_sq - 2.0 * xv + q_sq, 0.0))[None, :]


def l2_pallas(x: jax.Array, q: jax.Array, tile: int = TILE,
              interpret: bool | None = None) -> jax.Array:
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    g = n // tile
    scal = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(jnp.sum(q * q))
    out = pl.pallas_call(
        _l2_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, tile), jnp.float32),
        interpret=interpret,
    )(x, q.reshape(1, d), scal)
    return out.reshape(n)


def _l2_batch_kernel(x_ref, qt_ref, scal_ref, out_ref):
    x = x_ref[...]                     # (TILE, d)
    qt = qt_ref[...]                   # (d, B)
    q_sq = scal_ref[...][:, 0]         # (B,)
    xv = jax.lax.dot_general(x, qt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (TILE, B)
    x_sq = jnp.sum(x * x, axis=1)
    out_ref[...] = jnp.sqrt(jnp.maximum(
        x_sq[:, None] - 2.0 * xv + q_sq[None, :], 0.0))


def l2_batch_pallas(x: jax.Array, qs: jax.Array, tile: int = TILE,
                    interpret: bool | None = None) -> jax.Array:
    """Exact ||q_b - x_i|| for a batch of queries: one MXU matmul per tile.

    ``x`` (n, d) shared candidate vectors, ``qs`` (B, d).  Returns (B, n).
    """
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    b = qs.shape[0]
    g = n // tile
    scal = jnp.zeros((b, 128), jnp.float32).at[:, 0].set(
        jnp.sum(qs * qs, axis=1))
    out = pl.pallas_call(
        _l2_batch_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d, b), lambda i: (0, 0)),
            pl.BlockSpec((b, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(x, qs.T, scal)
    return out.T
