"""Pallas TPU kernels for the BBC hot paths, with jnp reference mirrors.

One module per kernel (fused_scan, bucket_hist, pq_adc, rabitq_est,
rabitq_fused, l2_rerank, shard_collect); ``ops.py`` wraps them behind the
pallas/ref backend switch and ``ref.py`` holds the jnp oracles.
"""
# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
